"""The annotation-service runtime: async request broker + budget ledger.

One :class:`AnnotationService` is one annotation campaign endpoint: a
seeded noisy :class:`~repro.annotation.oracle.AnnotatorPool`, a
:class:`RepeatPolicy` (how many votes per item, whether to top up
adaptively), the device-resident
:class:`~repro.annotation.aggregate.VoteAggregator`, and pricing — every
request round is charged per VOTE at the configured
:class:`~repro.core.cost.LabelingService` tier rates into the service's
own :class:`~repro.core.cost.CostLedger` (the budget ledger; an optional
hard ``budget`` refuses requests that would break it).

Request flow per batch (``annotate``):

1. rounds ``0 .. repeats-1`` ask one worker per item each (workers are
   assigned round-robin from the deterministic request cursor, so no item
   sees the same worker twice and the schedule replays identically after
   a resume);
2. with ``adaptive`` (Liao et al.'s good practice), the votes are
   aggregated after the base rounds and only items whose aggregated
   posterior confidence has NOT cleared ``confidence`` get another vote,
   round by round up to ``max_repeats`` — confident items stop costing
   money;
3. the final vote matrix aggregates (majority or Dawid-Skene EM, on
   device) into the labels handed back; per-worker agreement statistics
   and the latest EM confusion estimates are folded into the service
   state (persisted in campaign checkpoints).

``submit`` mirrors ``PoolSweepRunner.submit``: requests from one or many
campaigns batch onto the service's worker thread and return the sweep
runtime's :class:`~repro.serving.sweep.SweepFuture` handle, so callers
overlap their own work and synchronize at ``result()`` — the broker
serializes all state mutation on that one thread.

MCAL integration: tasks carry ``task.annotation = service`` and route
``human_label`` through :meth:`annotate`; ``SharedPool.buy_labels``
reads the per-call vote count (:attr:`votes_bought` delta) and charges
the CAMPAIGN ledger repeats-inclusive through ``pay_human`` — the
service ledger stays the service-side account of the same requests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.annotation.aggregate import AggregateConfig, VoteAggregator
from repro.annotation.oracle import AnnotatorPool
from repro.core.cost import CostLedger, LabelQuality, LabelingService
from repro.core.worker import SerialWorker
# the sweep runtime's async handle, shared rather than mirrored (the same
# convention FitEngine follows) so worker-handle hardening lands once
from repro.serving.sweep import SweepFuture as AnnotationFuture

AGGREGATORS = ("majority", "ds")


class BudgetExceeded(RuntimeError):
    """A request round would push the service ledger past its budget."""


@dataclasses.dataclass(frozen=True)
class RepeatPolicy:
    """Repeated-labeling policy: ``repeats`` votes per item up front;
    with ``adaptive``, items below ``confidence`` aggregated-posterior
    confidence keep receiving votes up to ``max_repeats``."""

    repeats: int = 1
    max_repeats: Optional[int] = None      # None -> repeats (no top-up)
    adaptive: bool = False
    confidence: float = 0.9
    aggregator: str = "majority"           # majority | ds

    def __post_init__(self):
        assert self.repeats >= 1
        assert self.aggregator in AGGREGATORS
        if self.max_repeats is not None:
            assert self.max_repeats >= self.repeats
        if self.adaptive:
            # a silent-no-op guard, not a nicety: with cap == repeats the
            # top-up loop is empty by construction, and a single-vote
            # majority's confidence is identically 1.0 so no row would
            # ever be selected — the flags would promise quality-driven
            # top-ups and deliver none
            assert self.cap > self.repeats, \
                "adaptive repeats needs max_repeats > repeats " \
                "(no room to top up)"
            assert self.repeats >= 2 or self.aggregator == "ds", \
                "adaptive majority needs repeats >= 2: a single-vote " \
                "majority is always 100% confident, so no item would " \
                "ever be topped up (use aggregator='ds' for " \
                "single-vote adaptivity)"

    @property
    def cap(self) -> int:
        return self.max_repeats if self.max_repeats is not None \
            else self.repeats


class AnnotationService:
    """One annotation endpoint: noisy worker pool + aggregation policy +
    per-vote pricing.  See the module docstring for the request flow."""

    def __init__(self, pool: AnnotatorPool,
                 policy: RepeatPolicy = RepeatPolicy(),
                 pricing: LabelingService = LabelingService("annotation",
                                                            0.04),
                 budget: Optional[float] = None,
                 agg_cfg: AggregateConfig = AggregateConfig()):
        assert policy.cap <= pool.n_workers, \
            "max_repeats cannot exceed the worker pool (one vote each)"
        self.pool = pool
        self.policy = policy
        self.pricing = pricing
        self.budget = budget
        self.aggregator = VoteAggregator(pool.cfg.num_classes, agg_cfg)
        self.ledger = CostLedger()             # the service budget ledger
        self.trace = None                      # campaign event bus (attach_trace)
        self.metrics = None                    # runtime metrics (attach_metrics)
        self.faults = None                     # chaos injector (attach_faults)
        self.retry = None                      # faults.RetryPolicy: re-issue
        #                                        transiently-failed batches
        # -- persisted runtime state (state_dict) --------------------------
        self._cursor = 0                       # request-batch counter: the
        #                                        worker-schedule offset
        W = pool.n_workers
        self._agree = np.zeros(W, np.int64)    # votes == aggregated label
        self._count = np.zeros(W, np.int64)    # votes cast, per worker
        self._conf_sum = 0.0                   # sum of per-item aggregated
        self._conf_n = 0                       # confidence (residual est.)
        self._confusion_est: Optional[np.ndarray] = None  # last EM (W,C,C)
        self._exec: Optional[SerialWorker] = None
        # one batch at a time: direct annotate() calls and brokered
        # submit() batches serialize here, so the cursor advance, the
        # ledger's read-modify-writes, and the worker statistics can
        # never interleave.  A service shared by several campaigns hands
        # each one an :class:`AnnotationSession` (per-tenant cursor +
        # vote accounting); attaching the bare service to two campaigns
        # remains unsupported, because the votes-bought delta
        # ``SharedPool.buy_labels`` reads would see the other buyer's
        # requests.
        self._lock = threading.Lock()

    def attach_trace(self, trace) -> None:
        """Wire the campaign event bus through the broker: every service-
        ledger charge emits (as ledger="service", distinct from the
        campaign ledger's stream), and each request batch emits its vote
        rounds, adaptive top-ups, and an annotator-quality snapshot."""
        self.trace = trace
        self.ledger.trace = trace
        self.ledger.trace_name = "service"

    def attach_metrics(self, metrics) -> None:
        """Wire the runtime metrics registry (repro.obs) through the
        request path: per-batch spans, EM/top-up round counters, and the
        broker queue depth/wait telemetry.  None (the default) keeps
        every instrumented site a free no-op."""
        self.metrics = metrics
        self.aggregator.metrics = metrics

    def attach_faults(self, faults, retry=None) -> None:
        """Wire the chaos/resilience seam: every request batch ticks the
        ``annotation.request`` fault site BEFORE any charge or cursor
        advance, and with a :class:`~repro.faults.RetryPolicy` attached
        transiently-failed batches are re-issued (safe: votes are
        counter-free hashes of (pool seed, worker, item), so a re-issued
        request yields the identical vote matrix, and a failed attempt
        charges nothing — retries charge exactly once)."""
        self.faults = faults
        if retry is not None:
            self.retry = retry

    def _emit(self, kind: str, **payload) -> None:
        if self.trace is not None:
            self.trace.emit(kind, **payload)

    # -- introspection -----------------------------------------------------
    @property
    def votes_bought(self) -> int:
        """Priced annotation requests so far (the campaign charging hook:
        ``SharedPool.buy_labels`` charges the delta across one call)."""
        return self.ledger.human_votes

    @property
    def request_cursor(self) -> int:
        return self._cursor

    def avg_repeats(self) -> float:
        """Measured votes per purchased label (policy.repeats before any
        purchase)."""
        if self.ledger.human_labels == 0:
            return float(self.policy.repeats)
        return self.ledger.human_votes / self.ledger.human_labels

    def worker_accuracy(self) -> np.ndarray:
        """Per-worker empirical agreement with the aggregated labels —
        the online annotator-quality estimate (1.0 for unseen workers)."""
        with np.errstate(invalid="ignore"):
            acc = self._agree / np.maximum(self._count, 1)
        return np.where(self._count > 0, acc, 1.0)

    def confusion_estimate(self) -> Optional[np.ndarray]:
        """Latest Dawid-Skene per-worker confusion estimate (None until a
        ``ds``-aggregated batch has run)."""
        return None if self._confusion_est is None \
            else self._confusion_est.copy()

    def estimated_residual_error(self) -> float:
        """Running estimate of the aggregated-label error: one minus the
        mean aggregated-posterior confidence of the chosen labels (the
        standard posterior-risk proxy); falls back to the pool's analytic
        majority error before any batch has run."""
        if self._conf_n == 0:
            return self.pool.expected_majority_error(self.policy.repeats)
        return max(1.0 - self._conf_sum / self._conf_n, 0.0)

    def expected_quality(self) -> LabelQuality:
        """The :class:`LabelQuality` a campaign should fold into its
        accuracy target and joint search — analytic (from the pool's true
        confusion matrices + the policy), so it is deterministic at
        campaign-config time.  Pessimistic for ``ds``/adaptive policies
        (it models a plain ``repeats``-vote majority); :meth:`calibrate`
        measures the real thing."""
        return LabelQuality(
            residual_error=self.pool.expected_majority_error(
                self.policy.repeats),
            avg_repeats=float(self.policy.repeats))

    def calibrate(self, n: int = 2048) -> LabelQuality:
        """MEASURED label quality: run the full policy + aggregation
        machinery over a seeded synthetic calibration batch with known
        ground truth and report the observed residual error and votes per
        label.  Deterministic per (pool seed, policy, n) — a resumed
        campaign reconstructs the identical quality config — and charge-
        free: the batch runs on a cloned pool (disjoint Philox streams,
        so calibration never reuses the randomness of real requests) and
        a throwaway service, leaving this service's cursor, ledger, and
        statistics untouched.  Unlike :meth:`expected_quality` this sees
        what Dawid-Skene and adaptive top-ups actually buy (spammers
        down-weighted, hard items topped up)."""
        cfg = self.pool.cfg
        # the SAME worker population (profiles + confusion matrices), on
        # vote-randomness streams salted away from every real request —
        # reseeding the pool itself would resample the per-worker noise
        # jitter and measure a different crowd than the one answering
        clone = AnnotationService(
            AnnotatorPool(cfg, draw_salt=0x5CA1AB1E),
            self.policy, pricing=self.pricing,
            agg_cfg=self.aggregator.cfg)
        rng = np.random.default_rng(cfg.seed)
        gt = rng.integers(0, cfg.num_classes, n)
        labels = clone.annotate(np.arange(n), gt)
        return LabelQuality(residual_error=float(np.mean(labels != gt)),
                            avg_repeats=clone.avg_repeats())

    # -- the request path --------------------------------------------------
    def _within_budget(self, n_votes: int) -> bool:
        if self.budget is None:
            return True
        due = self.pricing.cost(n_votes, start=self.ledger.human_votes)
        return self.ledger.human + due <= self.budget + 1e-12

    def _topup_round(self, votes: np.ndarray, rows: np.ndarray,
                     idx: np.ndarray, true: np.ndarray, base: int, r: int):
        """One adaptive top-up round over the still-unsure ``rows``:
        worker ``(base + row + r) % W`` answers each — the continuation
        of ``AnnotatorPool.vote_matrix``'s schedule at round ``r``."""
        W = self.pool.n_workers
        w_of = (base + rows + r) % W
        for w in np.unique(w_of):
            sub = rows[w_of == w]
            votes[sub, w] = self.pool.annotate(idx[sub], true[sub], int(w))

    def annotate(self, idx: np.ndarray, true_labels: np.ndarray
                 ) -> np.ndarray:
        """Answer one label-request batch: collect votes per the policy,
        charge the ledger per vote round, return the aggregated labels
        (row-aligned with ``idx``).  Batches serialize on the service
        lock — a direct call and a brokered one can never interleave.

        Budget semantics are transactional: the mandatory base rounds
        (``N * repeats`` votes) are affordability-checked UP FRONT —
        :class:`BudgetExceeded` is raised before anything is charged,
        counted, or cursor-advanced, so a refused batch leaves no
        phantom state and a retried one replays identically.  Adaptive
        top-up rounds are best-effort within the remaining budget: an
        unaffordable round just stops the topping-up."""
        labels, _votes = self.annotate_counted(idx, true_labels)
        return labels

    def annotate_counted(self, idx: np.ndarray, true_labels: np.ndarray
                         ) -> Tuple[np.ndarray, int]:
        """:meth:`annotate` plus the EXACT priced vote count this call
        consumed, measured inside the lock — the per-call accounting the
        votes-bought delta protocol approximates from outside it."""
        idx = np.asarray(idx, np.int64)
        true = np.asarray(true_labels, np.int64)

        def attempt():
            # read-modify-write of the cursor stays atomic per attempt:
            # a failed attempt (the fault fires pre-mutation) leaves
            # cursor, ledger, and statistics untouched, so the retry
            # replays the identical worker schedule and charges once
            with self._lock:
                out = self._annotate_locked(idx, true, self._cursor,
                                            self.policy)
                self._cursor = out[2]
                return out

        labels, votes, _ = self._run_request(attempt)
        return labels, votes

    def _run_request(self, attempt, *, retry=None, trace=None):
        """One request batch through the resilience layer: run
        ``attempt`` under the retry policy (session override first,
        service default second, none = a single bare attempt).  Each
        re-issue emits a ``retry`` observability event and bumps
        ``retries_total``; exhaustion raises
        :class:`~repro.faults.RetryExhausted` (terminal — the fleet
        layer quarantines)."""
        retry = retry if retry is not None else self.retry
        if retry is None:
            return attempt()
        emitter = trace if trace is not None else self.trace

        def notify(attempt_no, exc, delay):
            if emitter is not None:
                emitter.emit("retry", site="annotation.request",
                             attempt=int(attempt_no),
                             error=type(exc).__name__, delay=float(delay))
            if self.metrics is not None:
                self.metrics.inc("retries_total", site="annotation.request")

        return retry.call(attempt, site="annotation.request", notify=notify)

    def _annotate_locked(self, idx: np.ndarray, true: np.ndarray,
                         cursor: int, pol: RepeatPolicy,
                         faults=None, timeout: Optional[float] = None
                         ) -> Tuple[np.ndarray, int, int]:
        faults = faults if faults is not None else self.faults
        if faults is not None:
            # the injection seam sits BEFORE the metrics span and before
            # any mutation: a fault here models the request never
            # reaching the backend — nothing was charged or counted
            if timeout is None and self.retry is not None:
                timeout = self.retry.timeout
            faults.check("annotation.request", timeout=timeout)
        if self.metrics is None:
            return self._annotate_impl(idx, true, cursor, pol)
        with self.metrics.span("annotate"):
            return self._annotate_impl(idx, true, cursor, pol)

    def _aggregate(self, resident, pol: RepeatPolicy):
        """One device aggregation round (majority or Dawid-Skene EM),
        timed when metrics are attached."""
        if self.metrics is None:
            return self.aggregator.aggregate_resident(resident,
                                                      pol.aggregator)
        t0 = time.perf_counter()
        out = self.aggregator.aggregate_resident(resident, pol.aggregator)
        self.metrics.observe("annotation_agg_seconds",
                             time.perf_counter() - t0,
                             aggregator=pol.aggregator)
        self.metrics.inc("annotation_agg_rounds_total",
                         aggregator=pol.aggregator)
        return out

    def _annotate_impl(self, idx: np.ndarray, true: np.ndarray,
                       cursor: int, pol: RepeatPolicy
                       ) -> Tuple[np.ndarray, int, int]:
        """One request batch under the lock: ``(labels, votes_spent,
        next_cursor)``.  The cursor is threaded through (not read off
        ``self``) so per-tenant :class:`AnnotationSession` cursors make
        each tenant's worker schedule — hence its vote streams — a pure
        function of its OWN request history, independent of how sibling
        tenants interleave on the shared service.  Likewise the policy is
        a parameter: sessions may carry a downgraded (fewer-repeats)
        policy while the service default stays put."""
        N = len(idx)
        if N == 0:
            return np.zeros((0,), np.int64), 0, cursor
        if not self._within_budget(N * pol.repeats):
            due = self.pricing.cost(N * pol.repeats,
                                    start=self.ledger.human_votes)
            raise BudgetExceeded(
                f"batch of {N} labels x {pol.repeats} votes (${due:.2f}) "
                f"would exceed the ${self.budget:.2f} annotation budget "
                f"(spent ${self.ledger.human:.2f})")
        base, cursor = cursor, cursor + 1
        # base rounds ARE the round-robin schedule the oracle exposes
        # (one shared implementation — tests/benchmarks build the exact
        # matrices campaigns aggregate through the same method)
        votes = self.pool.vote_matrix(idx, true, pol.repeats, base)
        spent = N * pol.repeats
        self.ledger.pay_human(N, self.pricing, votes=N * pol.repeats)
        self._emit("vote_round", n=int(N), repeats=int(pol.repeats),
                   votes=int(N * pol.repeats), cursor=int(base),
                   aggregator=pol.aggregator)
        # the batch stays device-resident across top-up rounds: one full
        # upload here, then only the rows a round changed scatter in
        # (the FitEngine.extend_resident convention) — re-aggregation
        # never re-materializes or re-uploads the (N, W) matrix
        resident = self.aggregator.upload(votes)
        if self.metrics is not None:
            self.metrics.inc("annotation_labels_total", float(N))
            self.metrics.inc("annotation_votes_total",
                             float(N * pol.repeats))
        labels, conf, ds = self._aggregate(resident, pol)
        if pol.adaptive:
            rows = np.arange(N)
            for r in range(pol.repeats, pol.cap):
                active = rows[conf < pol.confidence]
                if len(active) == 0 or \
                        not self._within_budget(len(active)):
                    break
                self.ledger.pay_votes(len(active), self.pricing)
                spent += len(active)
                self._emit("topup", round=int(r), n=int(len(active)),
                           cursor=int(base))
                if self.metrics is not None:
                    self.metrics.inc("annotation_topup_rounds_total")
                    self.metrics.inc("annotation_votes_total",
                                     float(len(active)))
                self._topup_round(votes, active, idx, true, base, r)
                resident = self.aggregator.scatter(resident, active,
                                                   votes[active])
                labels, conf, ds = self._aggregate(resident, pol)
        # -- fold batch statistics into the service state ------------------
        # single-vote batches carry no quality signal (one vote always
        # "agrees" with its own aggregate and majority confidence is
        # identically 1.0): skip the fold so the estimators keep the
        # analytic prior instead of reporting a perfect crowd
        if pol.cap > 1:
            cast = votes >= 0
            match = cast & (votes == labels[:, None].astype(np.int32))
            self._count += cast.sum(axis=0)
            self._agree += match.sum(axis=0)
            self._conf_sum += float(np.sum(conf))
            self._conf_n += N
        if ds is not None:
            self._confusion_est = np.asarray(ds.confusion, np.float64)
        if pol.cap > 1:
            # quality telemetry for the live report's drift view — one
            # snapshot per statistics fold, so the trace shows the
            # estimators converging request batch by request batch
            self._emit("annotator_snapshot",
                       worker_accuracy=[float(a) for a
                                        in self.worker_accuracy()],
                       residual_error=float(
                           self.estimated_residual_error()),
                       avg_repeats=float(self.avg_repeats()))
        return labels, spent, cursor

    # -- the broker --------------------------------------------------------
    def _executor(self) -> SerialWorker:
        if self._exec is None:
            self._exec = SerialWorker("annotation")
        return self._exec

    def submit(self, idx: np.ndarray, true_labels: np.ndarray
               ) -> AnnotationFuture:
        """Broker a label-request batch onto the service worker thread
        (requests from any number of campaigns serialize there, so state
        mutation and charging stay single-threaded); synchronize at
        ``result()`` — the aggregated labels."""
        idx = np.asarray(idx, np.int64).copy()
        true = np.asarray(true_labels, np.int64).copy()
        m = self.metrics
        if m is None:
            return AnnotationFuture(
                self._executor().submit(self.annotate, idx, true))
        m.add_gauge("queue_depth", 1, queue="annotation")
        t_sub = time.perf_counter()

        def job():
            # wait = broker latency: submit -> the worker picks it up
            m.observe("queue_wait_seconds", time.perf_counter() - t_sub,
                      queue="annotation")
            try:
                return self.annotate(idx, true)
            finally:
                m.add_gauge("queue_depth", -1, queue="annotation")

        return AnnotationFuture(self._executor().submit(job))

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Idempotent service shutdown: join the broker thread (no-op if
        nothing was ever submitted).  ``submit`` afterwards raises;
        synchronous ``annotate`` calls remain valid."""
        if self._exec is not None:
            self._exec.close()

    def __enter__(self) -> "AnnotationService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def session(self, name: str = "tenant") -> "AnnotationSession":
        """A per-tenant view of this service — the supported shape for
        sharing one service across campaigns."""
        return AnnotationSession(self, name)

    # -- fault tolerance ---------------------------------------------------
    def state_dict(self) -> Dict:
        """JSON-serializable service state: the pending-request cursor,
        the budget ledger, per-worker agreement stats, and the latest EM
        confusion estimate — with the (seeded) pool and the persisted
        label store, a resumed noisy-oracle campaign replays future
        requests bit-identically."""
        return {
            "cursor": int(self._cursor),
            "ledger": self.ledger.as_dict(),
            "agree": self._agree.tolist(),
            "count": self._count.tolist(),
            "conf_sum": float(self._conf_sum),
            "conf_n": int(self._conf_n),
            "confusion_est": (None if self._confusion_est is None
                              else self._confusion_est.tolist()),
        }

    def load_state_dict(self, s: Dict):
        self._cursor = int(s["cursor"])
        self.ledger = CostLedger.from_dict(s["ledger"])
        if self.trace is not None:
            # from_dict built a fresh ledger: re-wire the event bus
            self.ledger.trace = self.trace
            self.ledger.trace_name = "service"
        self._agree = np.asarray(s["agree"], np.int64)
        self._count = np.asarray(s["count"], np.int64)
        assert len(self._agree) == self.pool.n_workers, \
            "checkpoint was cut against a different worker pool"
        self._conf_sum = float(s["conf_sum"])
        self._conf_n = int(s["conf_n"])
        ce = s.get("confusion_est")
        self._confusion_est = None if ce is None \
            else np.asarray(ce, np.float64)


class AnnotationSession:
    """One tenant's view of a SHARED :class:`AnnotationService`.

    The shared pieces stay on the service — the worker pool, the
    aggregation engine and its compile cache, pricing, the service
    ledger, the broker thread, the batch lock.  The per-tenant pieces
    live here:

    * the **request cursor**: the worker round-robin schedule (hence the
      exact vote stream each item sees) is a pure function of this
      session's own request history, so a tenant's labels are
      bit-identical whether sibling tenants interleave with it or not,
      and a preempted-and-resumed tenant never perturbs its siblings;
    * the **vote/label counters** ``SharedPool.buy_labels`` charges
      against: the ``votes_bought`` delta a campaign reads across one
      ``human_label`` call can only ever see this session's requests —
      charges cannot cross-talk (tests/test_orchestrator.py proves it
      under interleaved submits);
    * an optional **policy override** (the fleet controller's
      ``shrink_votes`` downgrade swaps in a fewer-repeats policy for
      this tenant only).

    A session satisfies the same task-facing surface the bare service
    does (``annotate``/``submit``/``votes_bought``/``state_dict``/
    quality estimators), so ``task.annotation = service.session(...)``
    is a drop-in."""

    def __init__(self, service: AnnotationService, name: str = "tenant"):
        self.service = service
        self.name = name
        self._cursor = 0
        self._votes = 0
        self._labels = 0
        self._policy: Optional[RepeatPolicy] = None
        self.trace = None
        # per-tenant resilience overrides (None = the service's): a chaos
        # harness can fail ONE tenant's requests while siblings run clean
        self._faults = None
        self._retry = None

    # -- shared-surface delegation -----------------------------------------
    @property
    def pool(self) -> AnnotatorPool:
        return self.service.pool

    @property
    def pricing(self) -> LabelingService:
        return self.service.pricing

    @property
    def policy(self) -> RepeatPolicy:
        return self._policy or self.service.policy

    def expected_quality(self) -> LabelQuality:
        return self.service.expected_quality()

    def calibrate(self, n: int = 2048) -> LabelQuality:
        return self.service.calibrate(n)

    def estimated_residual_error(self) -> float:
        return self.service.estimated_residual_error()

    def worker_accuracy(self) -> np.ndarray:
        return self.service.worker_accuracy()

    def confusion_estimate(self) -> Optional[np.ndarray]:
        return self.service.confusion_estimate()

    # -- per-tenant accounting ---------------------------------------------
    @property
    def votes_bought(self) -> int:
        """THIS session's priced requests (the ``buy_labels`` delta
        protocol reads this — sibling sessions never move it)."""
        return self._votes

    @property
    def labels_bought(self) -> int:
        return self._labels

    @property
    def request_cursor(self) -> int:
        return self._cursor

    def avg_repeats(self) -> float:
        if self._labels == 0:
            return float(self.policy.repeats)
        return self._votes / self._labels

    def set_policy(self, policy: Optional[RepeatPolicy]) -> None:
        """Install a per-tenant policy override (None restores the
        service default) — the fleet controller's vote-shrink hook."""
        if policy is not None:
            assert policy.cap <= self.service.pool.n_workers
        self._policy = policy

    # -- the request path --------------------------------------------------
    def annotate(self, idx: np.ndarray, true_labels: np.ndarray
                 ) -> np.ndarray:
        """One request batch through the shared service, scheduled off
        THIS session's cursor.  Batches still serialize on the service
        lock; the session's counters update on the calling thread (one
        tenant drives one session — sessions are not themselves
        concurrency-safe, the service is)."""
        idx = np.asarray(idx, np.int64)
        true = np.asarray(true_labels, np.int64)
        svc = self.service
        retry = self._retry if self._retry is not None else svc.retry
        timeout = retry.timeout if retry is not None else None

        def attempt():
            with svc._lock:
                out = svc._annotate_locked(idx, true, self._cursor,
                                           self.policy, self._faults,
                                           timeout)
                self._cursor = out[2]
                return out

        labels, votes, _ = svc._run_request(attempt, retry=retry,
                                            trace=self.trace)
        self._votes += votes
        self._labels += len(idx)
        if self.trace is not None:
            self.trace.emit("vote_round", session=self.name,
                            n=int(len(idx)), votes=int(votes),
                            cursor=int(self._cursor - 1))
        return labels

    def submit(self, idx: np.ndarray, true_labels: np.ndarray
               ) -> AnnotationFuture:
        """Broker a batch onto the shared service worker thread.  The
        session's cursor/counters update on that worker before the
        future resolves, so a tenant that synchronizes at ``result()``
        reads its own accounting consistently."""
        idx = np.asarray(idx, np.int64).copy()
        true = np.asarray(true_labels, np.int64).copy()
        return AnnotationFuture(
            self.service._executor().submit(self.annotate, idx, true))

    # -- lifecycle ---------------------------------------------------------
    def attach_trace(self, trace) -> None:
        """Per-tenant observability only: the session emits its own vote
        rounds into the tenant trace.  The SHARED service ledger and
        batch telemetry are deliberately NOT wired here — their events
        interleave every tenant's requests and belong to the fleet
        trace, not to any one tenant's decision stream."""
        self.trace = trace

    def attach_metrics(self, metrics) -> None:
        """Runtime metrics are shared-service telemetry: delegate to the
        service registry (per-tenant attribution happens via the
        registry's bound labels on the calling thread, not here)."""
        self.service.attach_metrics(metrics)

    def attach_faults(self, faults, retry=None) -> None:
        """Per-SESSION chaos/retry override: only this tenant's request
        batches tick the injector (and retry under ``retry``) — the seam
        the quarantine acceptance test fails one tenant through while
        its siblings stay fault-free.  The service-level
        :meth:`AnnotationService.attach_faults` remains the
        whole-endpoint chaos switch."""
        self._faults = faults
        if retry is not None:
            self._retry = retry

    def close(self) -> None:
        """Sessions do not own the broker thread — closing one is a
        no-op (the service/fleet owner closes the service)."""

    # -- fault tolerance ---------------------------------------------------
    def state_dict(self) -> Dict:
        """Per-tenant session state only (cursor + counters): a resumed
        tenant replays ITS schedule bit-identically from here.  The
        shared service's state is fleet infrastructure and is persisted
        by the service owner, not per tenant."""
        return {"session": True, "cursor": int(self._cursor),
                "votes": int(self._votes), "labels": int(self._labels)}

    def load_state_dict(self, s: Dict):
        assert s.get("session"), \
            "checkpoint carries bare-service state, not a session's"
        self._cursor = int(s["cursor"])
        self._votes = int(s["votes"])
        self._labels = int(s["labels"])


def make_annotation_service(
        num_classes: int, *, n_workers: int = 5, noise: float = 0.2,
        spammer_frac: float = 0.0, repeats: int = 1,
        max_repeats: Optional[int] = None, adaptive: bool = False,
        confidence: float = 0.9, aggregator: str = "majority",
        pricing: LabelingService = LabelingService("annotation", 0.04),
        budget: Optional[float] = None, seed: int = 0) -> AnnotationService:
    """One-call construction of the full runtime (the launcher's and the
    tests' entry point)."""
    from repro.annotation.oracle import make_annotator_pool
    pool = make_annotator_pool(n_workers, num_classes, noise=noise,
                               spammer_frac=spammer_frac, seed=seed)
    return AnnotationService(
        pool, RepeatPolicy(repeats=repeats, max_repeats=max_repeats,
                           adaptive=adaptive, confidence=confidence,
                           aggregator=aggregator),
        pricing=pricing, budget=budget)
