"""Seeded noisy-annotator pools — the human side of the annotation service.

MCAL's premise is that ground truth comes from cloud annotation services,
yet the seed's ``Task.human_label`` was a free, instantaneous, PERFECT
oracle.  This module models the workers those services actually employ:
each worker answers label requests through a per-worker (C, C) row-
stochastic confusion matrix ``P(vote = l | true = c)``, drawn from one of
three profiles (the standard crowd taxonomy — Liao et al., Dawid-Skene):

* ``reliable``  — (1 - noise) on the diagonal, the rest spread uniformly;
  per-worker noise is jittered around the configured base rate so workers
  are statistically distinguishable (what Dawid-Skene EM estimates);
* ``spammer``   — answers uniformly at random, ignoring the item;
* ``biased``    — a reliable worker that additionally collapses a
  ``bias_strength`` share of its probability mass onto one preferred
  class (systematic class confusion).

Determinism contract: a worker's answer to an item is a fixed function of
``(pool seed, worker, item)`` (a consistent annotator — asking twice
returns the same vote), drawn through counter-based Philox streams exactly
like ``EmulatedTask``'s correctness draws.  This is what makes preempted
noisy-oracle campaigns resume bit-identically: replaying a request after a
restart reproduces the votes the lost process saw.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

PROFILES = ("reliable", "spammer", "biased")


@dataclasses.dataclass(frozen=True)
class AnnotatorConfig:
    n_workers: int = 5
    num_classes: int = 10
    noise: float = 0.2          # base per-vote error rate of reliable workers
    noise_jitter: float = 0.5   # per-worker rate in noise * (1 +/- jitter)
    spammer_frac: float = 0.0   # share of workers answering uniformly
    biased_frac: float = 0.0    # share with a systematic class bias
    bias_strength: float = 0.5  # mass a biased worker moves onto its class
    seed: int = 0

    def __post_init__(self):
        assert self.n_workers >= 1 and self.num_classes >= 2
        assert 0.0 <= self.noise < 1.0
        assert self.spammer_frac + self.biased_frac <= 1.0 + 1e-9


class AnnotatorPool:
    """``n_workers`` seeded noisy annotators answering per-worker label
    requests.  ``confusion`` is the (W, C, C) ground-truth confusion
    stack (row-stochastic over votes) — the quantity Dawid-Skene EM
    estimates and the tests compare its estimates against."""

    def __init__(self, cfg: AnnotatorConfig, draw_salt: int = 0):
        # draw_salt shifts ONLY the per-vote randomness streams, keeping
        # the worker population (profiles, confusion matrices) identical
        # — calibration batches measure the REAL workers on vote
        # randomness disjoint from any campaign request
        self.cfg = cfg
        self.draw_salt = int(draw_salt)
        W, C = cfg.n_workers, cfg.num_classes
        rng = np.random.default_rng(cfg.seed)
        n_spam = int(round(cfg.spammer_frac * W))
        n_bias = int(round(cfg.biased_frac * W))
        profiles: List[str] = (["spammer"] * n_spam + ["biased"] * n_bias +
                               ["reliable"] * (W - n_spam - n_bias))
        # seeded shuffle so profile assignment is not position-correlated
        # with the round-robin worker schedule downstream
        rng.shuffle(profiles)
        self.profiles: Tuple[str, ...] = tuple(profiles)
        conf = np.zeros((W, C, C), np.float64)
        for w, prof in enumerate(self.profiles):
            if prof == "spammer":
                conf[w] = 1.0 / C
                continue
            lo = cfg.noise * (1.0 - cfg.noise_jitter)
            hi = cfg.noise * (1.0 + cfg.noise_jitter)
            err = float(np.clip(rng.uniform(lo, hi), 0.0, 0.95))
            row = np.full((C, C), err / max(C - 1, 1))
            np.fill_diagonal(row, 1.0 - err)
            if prof == "biased":
                b = int(rng.integers(0, C))
                onto = np.zeros((C, C))
                onto[:, b] = 1.0
                row = (1.0 - cfg.bias_strength) * row + \
                    cfg.bias_strength * onto
            conf[w] = row
        self.confusion = conf
        self._cdf = np.cumsum(conf, axis=2)        # (W, C, C) inverse-CDF
        self._cdf[:, :, -1] = 1.0                  # guard fp round-off

    @property
    def n_workers(self) -> int:
        return self.cfg.n_workers

    # -- the determinism primitive ----------------------------------------
    def _draws(self, worker: int, idx: np.ndarray) -> np.ndarray:
        """Uniform draws per (seed, worker, item): a splitmix64-style
        integer hash of the item id under a per-(seed, worker) key, so
        the same request always sees the same randomness at O(batch)
        cost.  (A Generator stream indexed by item would need O(pool)
        draws per request round — at ImageNet pool sizes that is ~10MB
        of wasted uniforms per (worker, round).)"""
        key = (self.cfg.seed * 1_000_003 + worker * 7919 + 1 +
               self.draw_salt * 0x51ED2701) & 0xFFFFFFFFFFFFFFFF
        z = idx.astype(np.uint64) + np.uint64(key)
        z = z * np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
        return (z >> np.uint64(11)).astype(np.float64) / float(1 << 53)

    def annotate(self, idx: np.ndarray, true_labels: np.ndarray,
                 worker: int) -> np.ndarray:
        """One worker's votes on ``idx`` (global item ids) given the true
        labels — the per-worker inverse-CDF draw through the worker's
        confusion row.  Deterministic per (pool seed, worker, item)."""
        idx = np.asarray(idx, np.int64)
        true = np.asarray(true_labels, np.int64)
        assert 0 <= worker < self.cfg.n_workers
        if len(idx) == 0:
            return np.zeros((0,), np.int64)
        r = self._draws(worker, idx)
        cdf = self._cdf[worker][true]              # (n, C)
        return np.argmax(r[:, None] < cdf, axis=1).astype(np.int64)

    def vote_matrix(self, idx: np.ndarray, true_labels: np.ndarray,
                    repeats: int, base: int = 0) -> np.ndarray:
        """A round-robin ``(len(idx), W)`` vote matrix (-1 = not asked):
        row ``i`` gets votes from workers ``(base + i + r) % W`` for
        ``r < repeats`` — the annotation service's worker schedule,
        shared by the oracle-grid tests and the aggregation benchmark so
        both exercise the exact matrices campaigns produce."""
        idx = np.asarray(idx, np.int64)
        true = np.asarray(true_labels, np.int64)
        N, W = len(idx), self.cfg.n_workers
        votes = np.full((N, W), -1, np.int32)
        rows = np.arange(N)
        for r in range(min(repeats, W)):
            w_of = (base + rows + r) % W
            for w in np.unique(w_of):
                sub = rows[w_of == w]
                votes[sub, w] = self.annotate(idx[sub], true[sub], int(w))
        return votes

    # -- analytic quality -------------------------------------------------
    def per_vote_error(self) -> float:
        """Expected single-vote error under a uniform class prior,
        averaged over workers — the analytic per-annotator quality."""
        diag = np.einsum("wcc->wc", self.confusion)
        return float(1.0 - diag.mean())

    def expected_majority_error(self, repeats: int) -> float:
        """Analytic error of an R-vote majority under the mean per-vote
        error (ties split evenly) — the residual-error estimate a campaign
        folds into its accuracy target (``LabelQuality``).  Exact for
        binary symmetric workers; a standard upper-ish bound otherwise."""
        p = self.per_vote_error()
        R = max(int(repeats), 1)
        ks = np.arange(R + 1)
        from math import comb
        pmf = np.asarray([comb(R, int(k)) for k in ks], np.float64) * \
            p ** ks * (1.0 - p) ** (R - ks)
        err = float(pmf[ks > R / 2].sum())
        if R % 2 == 0:
            err += 0.5 * float(pmf[ks == R // 2].sum())
        return min(err, 1.0)


def make_annotator_pool(n_workers: int = 5, num_classes: int = 10, *,
                        noise: float = 0.2, spammer_frac: float = 0.0,
                        biased_frac: float = 0.0, seed: int = 0,
                        **kw) -> AnnotatorPool:
    return AnnotatorPool(AnnotatorConfig(
        n_workers=n_workers, num_classes=num_classes, noise=noise,
        spammer_frac=spammer_frac, biased_frac=biased_frac, seed=seed, **kw))
