"""Annotation-service runtime: the human side of MCAL, made realistic.

    AnnotatorPool / AnnotatorConfig    seeded noisy worker pools
    VoteAggregator                     device majority + Dawid-Skene EM
    majority_vote_host / dawid_skene_host   the NumPy reference oracles
    AnnotationService / RepeatPolicy   async request broker + budget ledger
    AnnotationSession                  per-tenant view of a shared service
    make_annotation_service            one-call construction
"""
from repro.annotation.aggregate import (AggregateConfig, DSResult,
                                        ResidentVotes, VoteAggregator,
                                        dawid_skene_host,
                                        majority_vote_host,
                                        vote_counts_host)
from repro.annotation.oracle import (AnnotatorConfig, AnnotatorPool,
                                     make_annotator_pool)
from repro.annotation.service import (AGGREGATORS, AnnotationService,
                                      AnnotationSession, BudgetExceeded,
                                      RepeatPolicy,
                                      make_annotation_service)
