"""Paper Fig. 5 / Fig. 6 / Fig. 11: sample-selection metrics.

Fig. 5: machine-labeling accuracy of samples ranked by L(.) = margin —
the most-confident slice must be near-perfect, falling with theta.
Fig. 6/11: M(.) comparison — uncertainty metrics (margin / entropy /
least-confidence) vs k-center on MCAL total cost; k-center must be worse
because its classifier machine-labels fewer samples (§3.3).

Runs on a LIVE task (real JAX MLP over synthetic features) so the ranking
actually comes from a trained classifier, not the emulator.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core import AMAZON, MCALConfig, LiveTask, run_mcal
from repro.core.selection import machine_label_error_curve
from repro.data.synth import make_classification


def run():
    rows = []
    x, y = make_classification(4000, num_classes=10, dim=32,
                               difficulty=0.35, seed=1)

    # Fig. 5: accuracy of margin-ranked slices from a trained classifier
    task = LiveTask(features=x, groundtruth=y, num_classes=10, epochs=30,
                    seed=1)
    idx = np.arange(1000)
    task.train(np.arange(1000, 2500), y[1000:2500])
    (stats, _), us = timed(task.score, idx)
    correct = task.eval_correct(idx, y[idx])
    curve = machine_label_error_curve(stats, correct, [0.1, 0.5, 1.0])
    rows.append(Row("fig5_margin_rank_err@0.1", us, f"{curve[0]:.3f}"))
    rows.append(Row("fig5_margin_rank_err@1.0", us, f"{curve[2]:.3f}"))
    assert curve[0] <= curve[2] + 1e-9, "ranking must concentrate errors"

    # Fig. 6/11: M(.) metric comparison on total MCAL cost
    for metric in ("margin", "entropy", "least_confidence", "kcenter"):
        task = LiveTask(features=x, groundtruth=y, num_classes=10,
                        epochs=30, c_u_nominal=2e-4, seed=1)
        res, us = timed(
            run_mcal, task, AMAZON,
            MCALConfig(seed=1, metric=metric, delta0_frac=0.02,
                       max_iters=25))
        rows.append(Row(f"fig11_mcal_{metric}", us,
                        f"cost=${res.total_cost:.0f};S={res.S_size}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
