"""Paper Fig. 5 / Fig. 6 / Fig. 11: sample-selection metrics + the
pool-scoring engine throughput.

Fig. 5: machine-labeling accuracy of samples ranked by L(.) = margin —
the most-confident slice must be near-perfect, falling with theta.
Fig. 6/11: M(.) comparison — uncertainty metrics (margin / entropy /
least-confidence) vs k-center on MCAL total cost; k-center must be worse
because its classifier machine-labels fewer samples (§3.3).

Pool scoring: the jit-compiled device-resident engine vs the seed host
loop over a >= 50k pool — MCAL's per-iteration hot path (the engine must
be >= 2x; in practice it is an order of magnitude on one host device).

k-center: the device greedy farthest-point engine
(``core.selection_device``) vs the host ``k_center_greedy`` loop at a
50k x 256 pool — exact chosen-index agreement asserted, >= 2x speedup
floor enforced in CI (``--kcenter``).

Runs on a LIVE task (real JAX MLP over synthetic features) so the ranking
actually comes from a trained classifier, not the emulator.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed, timed_best
from repro.core import (AMAZON, MCALConfig, LiveTask, PoolScoringEngine,
                        ScoringConfig, run_mcal, score_pool_reference)
from repro.core.selection import k_center_greedy, machine_label_error_curve
from repro.data.synth import make_classification


def run_scoring(pool: int = 50_000, dim: int = 32, classes: int = 10,
                microbatch: int = 2048, enforce: bool = False) -> list:
    """Engine vs seed host path on a >= 50k pool (throughput + speedup).

    ``enforce`` turns the >= 2x speedup into a hard assert (the CI gate);
    the figure-generating ``run()`` path only reports it."""
    import jax
    from repro.configs.base import ModelConfig
    from repro.models.registry import get_model

    cfg = ModelConfig(name="bench-scoring", family="mlp", num_layers=2,
                      d_model=64, num_classes=classes, input_dim=dim,
                      dtype="float32", remat="none")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    x = np.random.default_rng(0).normal(size=(pool, dim)).astype(np.float32)

    engine = PoolScoringEngine(model, ScoringConfig(microbatch=microbatch))
    engine.score_host(params, x)           # compile/warm
    score_pool_reference(model, params, x)  # warm (incl. ragged tail chunk)

    (host_stats, _), us_host = timed(score_pool_reference, model, params, x,
                                     repeat=3)
    (eng_stats, _), us_eng = timed(engine.score_host, params, x, repeat=3)
    assert float(np.max(np.abs(eng_stats.margin - host_stats.margin))) < 1e-5

    speedup = us_host / us_eng
    rows = [
        Row(f"pool_scoring_host_{pool}", us_host,
            f"{pool / (us_host / 1e6):.0f}samples/s"),
        Row(f"pool_scoring_engine_{pool}", us_eng,
            f"{pool / (us_eng / 1e6):.0f}samples/s;speedup={speedup:.1f}x"),
    ]
    if enforce:
        assert speedup >= 2.0, f"engine only {speedup:.2f}x over host path"
    return rows


def run_kcenter(pool: int = 50_000, dim: int = 256, k: int = 64,
                n_anchors: int = 16, enforce: bool = False) -> list:
    """Device k-center engine vs the host greedy loop at a 50k x 256 pool.

    Features are integer-valued float32 so every squared distance is exact
    and the two engines must return the IDENTICAL chosen-index sequence
    (the oracle contract of tests/test_selection_device.py) — asserted
    here too, so the speedup row can never come from a wrong answer.  The
    device leg times device-resident features (in MCAL they are emitted by
    the scoring sweep and never visit the host); the host loop pays its
    own numpy-side layout, as the seed implementation did.

    ``enforce`` turns the >= 2x speedup into a hard assert (the CI gate).
    """
    import jax.numpy as jnp
    from repro.core.selection_device import k_center_greedy_device

    rng = np.random.default_rng(0)
    x = rng.integers(0, 8, size=(pool, dim)).astype(np.float32)
    anchors = rng.integers(0, 8, size=(n_anchors, dim)).astype(np.float32)
    x_dev = jnp.asarray(x)

    k_center_greedy_device(x_dev, k, anchors=anchors)   # compile/warm
    host_sel, us_host = timed_best(k_center_greedy, x, k, anchors=anchors,
                                   repeat=3)
    dev_sel, us_dev = timed_best(k_center_greedy_device, x_dev, k,
                                 anchors=anchors, repeat=3)
    assert np.array_equal(host_sel, dev_sel), \
        "device k-center diverged from the host oracle"

    speedup = us_host / us_dev
    rows = [
        Row(f"kcenter_host_{pool}x{dim}_k{k}", us_host,
            f"{pool * k / (us_host / 1e6):.0f}cand*centers/s"),
        Row(f"kcenter_device_{pool}x{dim}_k{k}", us_dev,
            f"{pool * k / (us_dev / 1e6):.0f}cand*centers/s;"
            f"speedup={speedup:.1f}x"),
    ]
    if enforce:
        assert speedup >= 2.0, \
            f"device k-center only {speedup:.2f}x over host loop"
    return rows


def run():
    rows = list(run_scoring())
    rows += run_kcenter()
    x, y = make_classification(4000, num_classes=10, dim=32,
                               difficulty=0.35, seed=1)

    # Fig. 5: accuracy of margin-ranked slices from a trained classifier
    task = LiveTask(features=x, groundtruth=y, num_classes=10, epochs=30,
                    seed=1)
    idx = np.arange(1000)
    task.train(np.arange(1000, 2500), y[1000:2500])
    (stats, _), us = timed(task.score, idx)
    correct = task.eval_correct(idx, y[idx])
    curve = machine_label_error_curve(stats, correct, [0.1, 0.5, 1.0])
    rows.append(Row("fig5_margin_rank_err@0.1", us, f"{curve[0]:.3f}"))
    rows.append(Row("fig5_margin_rank_err@1.0", us, f"{curve[2]:.3f}"))
    assert curve[0] <= curve[2] + 1e-9, "ranking must concentrate errors"

    # Fig. 6/11: M(.) metric comparison on total MCAL cost
    for metric in ("margin", "entropy", "least_confidence", "kcenter"):
        task = LiveTask(features=x, groundtruth=y, num_classes=10,
                        epochs=30, c_u_nominal=2e-4, seed=1)
        res, us = timed(
            run_mcal, task, AMAZON,
            MCALConfig(seed=1, metric=metric, delta0_frac=0.02,
                       max_iters=25))
        rows.append(Row(f"fig11_mcal_{metric}", us,
                        f"cost=${res.total_cost:.0f};S={res.S_size}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scoring-only", action="store_true",
                    help="only the pool-scoring throughput rows (CI smoke)")
    ap.add_argument("--kcenter", action="store_true",
                    help="only the k-center engine rows, speedup floor "
                         "enforced (CI smoke)")
    ap.add_argument("--pool", type=int, default=50_000)
    args = ap.parse_args()
    if args.kcenter:
        rows = run_kcenter(pool=args.pool, enforce=True)
    elif args.scoring_only:
        rows = run_scoring(pool=args.pool, enforce=True)
    else:
        rows = run()
    for r in rows:
        print(r.csv())
