"""Fused-scan retrain engine vs the per-step host training loop.

MCAL retrains from scratch at every iteration (fixed epochs, cost
proportional to |B| — Eqn. 4), so the retrain loop is half the
machine-side cost of a campaign.  Two implementations of one retrain:

  fit_hostloop   the per-step host loop the seed shipped
                 (``FitEngine.fit_reference``: a numpy batch gather +
                 one h2d upload + one jitted-step dispatch per batch,
                 blocking every step) — the exact-agreement oracle and
                 the leg the CI gate measures the engine against;
  fit_fused      ``FitEngine.fit``: the whole fixed-epoch retrain as ONE
                 jit-compiled program — (x, y) uploaded once, epoch
                 shuffles from ``jax.random.permutation`` on device,
                 epochs x steps fused into a single ``lax.scan``,
                 (n, batch) pow2-bucketed through ``scoring.pack_shape``.

Both paths consume the identical permutation sequence, so ``--enforce``
(the CI gate) asserts EXACT param agreement AND that the fused engine is
>= 2x faster at the gate shape of a representative (|B|, epochs) grid.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed_best


def _setup(dim: int = 32, classes: int = 10):
    from repro.configs.base import ModelConfig, TrainConfig
    from repro.models.registry import get_model

    cfg = ModelConfig(name="bench-fit", family="mlp", num_layers=2,
                      d_model=64, num_classes=classes, input_dim=dim,
                      dtype="float32", remat="none")
    model = get_model(cfg)
    tc = TrainConfig(learning_rate=1e-2, schedule="constant",
                     weight_decay=1e-4, grad_clip=1.0)
    return model, tc


def _agree(params_a, params_b) -> bool:
    from repro import compat
    la, lb = compat.tree_leaves(params_a), compat.tree_leaves(params_b)
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(la, lb))


def run_fit(grid=((512, 10), (2048, 10), (2048, 40)),
            gate_shape=(2048, 40), dim: int = 32, classes: int = 10,
            batch: int = 256, enforce: bool = False) -> list:
    import jax
    from repro.training.fit_device import FitConfig, FitEngine

    model, tc = _setup(dim, classes)
    rng_data = np.random.default_rng(0)
    rows, gate_speedup = [], None
    for n, epochs in grid:
        x = rng_data.normal(size=(n, dim)).astype(np.float32)
        y = rng_data.integers(0, classes, n).astype(np.int32)
        engine = FitEngine(model, tc,
                           FitConfig(epochs=epochs, batch_size=batch))
        key = jax.random.key(0)

        def fused():
            params, losses = engine.fit(key, x, y)
            jax.block_until_ready(losses)
            return params

        def hostloop():
            params, losses = engine.fit_reference(key, x, y)
            jax.block_until_ready(losses)
            return params

        p_fused, p_host = fused(), hostloop()   # warm both compile paths
        assert _agree(p_fused, p_host), \
            f"fused engine diverged from the per-step host loop at " \
            f"(n={n}, epochs={epochs})"
        p_fused, us_fused = timed_best(fused, repeat=3)
        _, us_host = timed_best(hostloop, repeat=2)
        speedup = us_host / us_fused
        steps = epochs * engine.cache_keys()[-1][0] \
            if engine.cache_keys() else 0
        rows.append(Row(
            f"fit_fused_{n}_e{epochs}", us_fused,
            f"speedup={speedup:.2f}x_vs_hostloop;"
            f"host_us={us_host:.0f};exact_params=True",
            meta={"pool": n, "epochs": epochs, "batch": batch,
                  "speedup": round(speedup, 3),
                  "steps": int(steps)}))
        if (n, epochs) == gate_shape:
            gate_speedup = speedup

    if enforce:
        assert gate_speedup is not None, \
            f"gate shape {gate_shape} missing from the grid"
        assert gate_speedup >= 2.0, \
            f"fused retrain only {gate_speedup:.2f}x over the per-step " \
            f"host loop at {gate_shape}"
    return rows


def run_smoke() -> list:
    """CI smoke shapes: a short retrain plus the paper-default epochs=40
    at a mid-campaign |B| — the gate shape, where the fused win is
    measured widest (~2.7x) so the 2x floor holds margin against noisy
    CI hosts."""
    return run_fit(grid=((512, 8), (1024, 40)), gate_shape=(1024, 40),
                   enforce=True)


def run() -> list:
    """Full bench: the acceptance (|B|, epochs) grid with the >= 2x gate
    enforced at the paper-default epochs=40 retrain."""
    return run_fit(enforce=True)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--enforce", action="store_true",
                    help="assert the >= 2x speedup floor (the CI gate)")
    ap.add_argument("--smoke", action="store_true",
                    help="small-shape smoke mode (gate enforced)")
    args = ap.parse_args()
    for r in (run_smoke() if args.smoke else
              run_fit(enforce=args.enforce)):
        print(r.csv())
