"""Paper Tbl. 3 + §5.3: relaxing the accuracy requirement to eps = 10%.

Savings must increase vs eps = 5% and the measured labeling accuracy must
stay above 90% (paper reports 91.9% / 94.7% / 98.4%).

Both campaign cells per dataset (eps=5% and eps=10%) run through
``common.mcal_cell``, so ``--from-trace DIR`` reproduces the whole table
from stored traces.
"""
from __future__ import annotations

from benchmarks.common import Row, add_trace_arg, mcal_cell
from repro.core import AMAZON, MCALConfig, make_emulated_task
from repro.core.emulator import DATASETS


def run(trace_dir=None):
    rows = []
    for ds in ("fashion", "cifar10", "cifar100"):
        full = DATASETS[ds]["full"] * AMAZON.price_per_label
        res5, _, src5 = mcal_cell(
            f"tbl3_{ds}_eps5",
            lambda ds=ds: make_emulated_task(ds, "resnet18", seed=0),
            AMAZON, MCALConfig(seed=0, eps_target=0.05),
            trace_dir=trace_dir)
        res10, us, src10 = mcal_cell(
            f"tbl3_{ds}_eps10",
            lambda ds=ds: make_emulated_task(ds, "resnet18", seed=0),
            AMAZON, MCALConfig(seed=0, eps_target=0.10),
            trace_dir=trace_dir)
        rows.append(Row(
            f"tbl3_{ds}_eps10", us,
            f"save5={1 - res5.total_cost / full:.1%};"
            f"save10={1 - res10.total_cost / full:.1%};"
            f"acc10={1 - res10.measured_error:.3f};"
            f"relaxing_helps={res10.total_cost <= res5.total_cost * 1.02}",
            meta={"source": src10, "source_eps5": src5}))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    add_trace_arg(ap)
    for r in run(trace_dir=ap.parse_args().from_trace):
        print(r.csv())
