"""Paper Tbl. 3 + §5.3: relaxing the accuracy requirement to eps = 10%.

Savings must increase vs eps = 5% and the measured labeling accuracy must
stay above 90% (paper reports 91.9% / 94.7% / 98.4%).
"""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import AMAZON, MCALConfig, make_emulated_task, run_mcal
from repro.core.emulator import DATASETS


def run():
    rows = []
    for ds in ("fashion", "cifar10", "cifar100"):
        full = DATASETS[ds]["full"] * AMAZON.price_per_label
        res5 = run_mcal(make_emulated_task(ds, "resnet18", seed=0), AMAZON,
                        MCALConfig(seed=0, eps_target=0.05))
        res10, us = timed(run_mcal, make_emulated_task(ds, "resnet18", seed=0),
                          AMAZON, MCALConfig(seed=0, eps_target=0.10))
        rows.append(Row(
            f"tbl3_{ds}_eps10", us,
            f"save5={1 - res5.total_cost / full:.1%};"
            f"save10={1 - res10.total_cost / full:.1%};"
            f"acc10={1 - res10.measured_error:.3f};"
            f"relaxing_helps={res10.total_cost <= res5.total_cost * 1.02}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
