"""Multi-tenant orchestrator benchmark: one mesh, one compile cache.

Two gated claims about running N matched-shape campaigns as a fleet over
ONE shared engine bundle (``launch.orchestrator.SharedEngines``):

* **shared compile cache** — tenant #1 pays the XLA compiles; tenants
  2..N run entirely out of the bundle's pow2 pack-shape cache (ZERO new
  programs at matched shapes, measured via ``cache_keys()``);
* **fleet wall-clock** — the concurrent shared-engine fleet completes in
  <= 0.75x the wall of the SAME campaigns run serially on fresh private
  engines (the per-campaign recompiles the fleet amortizes away).
"""
from __future__ import annotations

from benchmarks.common import Row, timed

WALL_GATE = 0.75                 # shared-fleet wall / fresh-serial wall
N_TENANTS = 4
CLASSES = 3
ENGINE_KW = dict(epochs=2, score_microbatch=128, sweep_page=128)


def _data(pool: int):
    from repro.data.synth import make_classification
    return make_classification(pool, num_classes=CLASSES, difficulty=0.3,
                               seed=0)


def _specs(n: int):
    from repro.core import MCALConfig
    from repro.core.tenant import TenantSpec
    return [TenantSpec(f"t{i}", priority=i % 2, seed=i,
                       cfg=MCALConfig(max_iters=2, delta0_frac=0.1,
                                      test_frac=0.2, seed=i))
            for i in range(n)]


def _fresh_serial(x, y, specs) -> None:
    """The baseline leg: the same campaigns, one at a time, each on
    fresh PRIVATE engines — every tenant pays its own compiles."""
    from repro.core import AMAZON, MCALCampaign
    from repro.core.task import LiveTask
    for s in specs:
        task = LiveTask(features=x, groundtruth=y, num_classes=CLASSES,
                        seed=s.seed, epochs=ENGINE_KW["epochs"],
                        score_microbatch=ENGINE_KW["score_microbatch"],
                        sweep_page=ENGINE_KW["sweep_page"])
        camp = MCALCampaign(task, AMAZON, s.cfg)
        try:
            camp.run()
        finally:
            camp.close()


def _shared_fleet(x, y, specs) -> None:
    """The fleet leg: one SharedEngines bundle, concurrent rounds."""
    from repro.core import AMAZON
    from repro.launch.orchestrator import build_fleet
    orch = build_fleet(x, y, specs, service=AMAZON, engine_kw=ENGINE_KW,
                       concurrent=True)
    try:
        orch.run()
    finally:
        orch.close()


def _cache_reuse(x, y, specs):
    """Compiled-program counts after each tenant's full campaign over
    one shared bundle — the gate reads counts[-1] - counts[0]."""
    from repro.core import AMAZON, MCALCampaign
    from repro.core.task import LiveTask
    from repro.launch.orchestrator import SharedEngines
    counts = []
    with SharedEngines.build(x.shape[1], CLASSES, **ENGINE_KW) as eng:
        for s in specs:
            task = LiveTask(features=x, groundtruth=y,
                            num_classes=CLASSES, seed=s.seed, engines=eng)
            MCALCampaign(task, AMAZON, s.cfg).run()
            counts.append(eng.compiled_count())
    return counts


def run_smoke(enforce: bool = True, pool: int = 512,
              tenants: int = N_TENANTS):
    x, y = _data(pool)
    specs = _specs(tenants)

    counts, cache_us = timed(_cache_reuse, x, y, specs)
    new_after_t1 = counts[-1] - counts[0]
    if enforce:
        assert new_after_t1 == 0, (
            f"tenants 2..{tenants} compiled {new_after_t1} new programs "
            f"at matched shapes — the shared compile cache missed "
            f"(counts per tenant: {counts})")

    _, serial_us = timed(_fresh_serial, x, y, specs)
    _, shared_us = timed(_shared_fleet, x, y, specs)
    ratio = shared_us / serial_us
    if enforce:
        assert ratio <= WALL_GATE, (
            f"shared-engine fleet took {ratio:.2f}x the fresh-serial "
            f"wall (gate <= {WALL_GATE:.2f}x): {shared_us:.0f}us vs "
            f"{serial_us:.0f}us for {tenants} tenants, pool {pool}")

    return [
        Row("orchestrator_cache", cache_us,
            f"programs={counts[0]};new_after_t1={new_after_t1};gate=0",
            meta={"pool": pool, "tenants": tenants,
                  "compiled_counts": counts,
                  "new_after_t1": new_after_t1}),
        Row("orchestrator_fleet", shared_us,
            f"speedup={serial_us / shared_us:.2f}x;"
            f"gate>={1.0 / WALL_GATE:.2f}x;serial_us={serial_us:.0f}",
            meta={"pool": pool, "tenants": tenants,
                  "wall_ratio": ratio, "gate": WALL_GATE}),
    ]


def run():
    return run_smoke(enforce=False, pool=2000)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in (run_smoke() if args.smoke else run()):
        print(row.csv())
