"""Fault-injection benchmark: chaos transparency + injector overhead.

Two gated claims about the resilience layer (``repro.faults``):

* recovery is TRANSPARENT — a noisy adaptive-DS emulated campaign under
  the standard transient FaultPlan (flaky annotation backend, torn
  trace write) must COMPLETE, and its decisions, ledger, and trace must
  be bit-identical to the fault-free sibling (``trace.replay.diff``
  clean over the decision kinds);
* the injection seams are effectively free — the identical campaign
  with an EMPTY-plan injector attached at every seam (every request,
  broker job, flush, and iteration ticks the injector; nothing ever
  fires) must run within 5% of the uninstrumented campaign.

The smoke leg leaves its chaos trace at ``artifacts/FAULTS_smoke.jsonl``
next to the other bench artifacts.
"""
from __future__ import annotations

from benchmarks.common import Row, artifact_path, timed_best

OVERHEAD_GATE = 0.05            # injected/plain - 1, enforced in smoke
TRACE_NAME = "FAULTS_smoke.jsonl"
POOL = 20000
CHAOS_SEED = 0


def _campaign(trace_path=None, faults=None, retry=None):
    """One noisy adaptive-DS emulated campaign; returns MCALResult.
    Fresh task + annotation service per call (both are stateful)."""
    from repro.annotation import make_annotation_service
    from repro.core import AMAZON, MCALConfig, make_emulated_task
    from repro.core.mcal import MCALCampaign

    ann = make_annotation_service(
        10, noise=0.2, repeats=3, max_repeats=5, adaptive=True,
        aggregator="ds", pricing=AMAZON, seed=0)
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=POOL)
    task.annotation = ann
    cfg = MCALConfig(seed=0, label_quality=ann.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    trace = None
    if trace_path is not None:
        from repro.trace import TraceStore
        trace = TraceStore(trace_path, "smoke-chaos-s0")
        camp.attach_trace(trace)
    if faults is not None:
        camp.attach_faults(faults, retry)
    try:
        return camp.run()
    finally:
        if trace is not None:
            trace.close()


def run_smoke(enforce: bool = True, repeat: int = 3):
    from repro.faults import FaultInjector, FaultPlan, RetryPolicy
    from repro.trace import diff

    # -- transparency: chaos run == fault-free run, bit for bit --------
    chaos_path = artifact_path(TRACE_NAME)
    clean_path = artifact_path("FAULTS_smoke_clean.jsonl")
    inj = FaultInjector(FaultPlan.standard_transient(CHAOS_SEED))
    res_chaos = _campaign(chaos_path, inj,
                          RetryPolicy(seed=CHAOS_SEED, sleep_scale=0.0))
    res_clean = _campaign(clean_path)
    d = diff(chaos_path, clean_path)
    transparent = (d is None
                   and res_chaos.ledger == res_clean.ledger
                   and res_chaos.decision == res_clean.decision
                   and res_chaos.total_cost == res_clean.total_cost)
    if enforce:
        assert inj.fired > 0, \
            "the standard transient plan never fired — nothing was tested"
        assert transparent, (
            f"chaos run diverged from its fault-free sibling: "
            f"diff={d}, ${res_chaos.total_cost} vs ${res_clean.total_cost}")

    # -- overhead: empty-plan injector at every seam vs none -----------
    res_plain, plain_us = timed_best(_campaign, repeat=repeat)
    idle = FaultInjector(FaultPlan())           # every seam ticks; none fire
    res_idle, idle_us = timed_best(
        _campaign, None, idle, RetryPolicy(sleep_scale=0.0), repeat=repeat)
    assert res_idle.total_cost == res_plain.total_cost, \
        "an idle injector changed the campaign's decisions"
    overhead = idle_us / plain_us - 1.0
    if enforce:
        assert overhead <= OVERHEAD_GATE, (
            f"idle-injector overhead {overhead:.1%} exceeds the "
            f"{OVERHEAD_GATE:.0%} gate "
            f"({idle_us:.0f}us injected vs {plain_us:.0f}us plain)")

    ticks = sum(idle.counters().values())
    return [
        Row("faults_chaos", 0.0,
            f"fired={inj.fired};diff_clean={d is None};"
            f"transparent={transparent};cost=${res_chaos.total_cost:.0f}",
            meta={"fired": inj.fired, "transparent": bool(transparent),
                  "pool": POOL, "artifact": chaos_path}),
        Row("faults_idle_overhead", idle_us,
            f"overhead={overhead:+.1%};gate<={OVERHEAD_GATE:.0%};"
            f"plain_us={plain_us:.0f};seam_ticks={ticks}",
            meta={"overhead": overhead, "seam_ticks": ticks}),
    ]


def run():
    """Full-suite leg: same measurement, gates reported but not
    enforced (the smoke leg is the enforcing one)."""
    return run_smoke(enforce=False)


if __name__ == "__main__":
    for r in run_smoke():
        print(r.csv())
