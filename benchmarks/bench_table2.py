"""Paper Tbl. 2: oracle-assisted AL per DNN architecture.

For each (dataset x architecture): sweep delta, report the oracle's best
delta + cost + savings vs full human labeling — and confirm MCAL's Tbl. 1
cost beats every oracle-AL cell (the paper's headline comparison).

The per-dataset MCAL reference campaign runs through ``common.mcal_cell``
(``--from-trace DIR`` replays it from a stored trace); the oracle-AL
delta sweeps are baseline grids, not campaigns, and always run live.
"""
from __future__ import annotations

from benchmarks.common import Row, add_trace_arg, mcal_cell, timed
from repro.core import AMAZON, MCALConfig, make_emulated_task
from repro.core.baselines import oracle_al
from repro.core.emulator import DATASETS


def run(trace_dir=None):
    rows = []
    for ds in ("fashion", "cifar10", "cifar100"):
        mcal, _, src = mcal_cell(
            f"tbl2_{ds}_mcal",
            lambda ds=ds: make_emulated_task(ds, "resnet18", seed=0),
            AMAZON, MCALConfig(seed=0), trace_dir=trace_dir)
        full = DATASETS[ds]["full"] * AMAZON.price_per_label
        for arch in ("cnn18", "resnet18", "resnet50"):
            (best_d, best, _), us = timed(
                oracle_al, lambda: make_emulated_task(ds, arch, seed=0),
                AMAZON, deltas=(0.017, 0.033, 0.067, 0.10, 0.133, 0.167))
            rows.append(Row(
                f"tbl2_{ds}_{arch}", us,
                f"delta_opt={best_d};cost=${best.cost:.0f};"
                f"save={1 - best.cost / full:.1%};"
                f"mcal_cheaper={mcal.total_cost <= best.cost * 1.001}",
                meta={"mcal_source": src}))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    add_trace_arg(ap)
    for r in run(trace_dir=ap.parse_args().from_trace):
        print(r.csv())
