"""Observability-layer benchmark: metrics overhead + replay cleanliness.

Two gated claims about the runtime metrics layer (``repro.obs``):

* instrumentation is effectively free on the live path — a fully
  instrumented noisy adaptive-repeats campaign (every span, counter,
  compile-cache probe, and queue gauge active, metric events interleaved
  into the campaign trace) must run within 3% of the identical
  metrics-off campaign (best-of-N wall clock on both legs, both traced,
  so the gate isolates the METRICS cost from the trace cost
  ``bench_trace`` already gates);
* metrics never contaminate the decision record — ``trace.diff`` between
  the metrics-on and metrics-off sibling traces must be clean (metric
  events are observability kinds; the replay stream is byte-identical).

The smoke leg drops the instrumented trace, a Prometheus textfile
snapshot, and leaves the registry installed as the process default so
``benchmarks.run`` embeds its snapshot into ``BENCH_*.json``.
"""
from __future__ import annotations

from benchmarks.common import Row, artifact_path, timed_best

OVERHEAD_GATE = 0.03            # instrumented/plain - 1, enforced in smoke
POOL = 20000
TRACE_OFF = "OBS_metrics_off.jsonl"
TRACE_ON = "OBS_metrics_on.jsonl"
PROM_NAME = "metrics_smoke.prom"


def _campaign(trace_path, metrics=None):
    """One noisy adaptive-repeats emulated campaign, traced; optionally
    fully instrumented.  Fresh task + annotation service per call (both
    are stateful)."""
    from repro.annotation import make_annotation_service
    from repro.core import AMAZON, MCALConfig, make_emulated_task
    from repro.core.mcal import MCALCampaign
    from repro.trace import TraceStore

    ann = make_annotation_service(
        10, noise=0.2, repeats=3, max_repeats=5, adaptive=True,
        aggregator="ds", pricing=AMAZON, seed=0)
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=POOL)
    task.annotation = ann
    # the fine delta schedule runs ~17 iterations (vs 3 at the default):
    # a second-scale workload, so the 3% gate measures instrumentation
    # cost rather than scheduler jitter on a ~250ms campaign
    cfg = MCALConfig(seed=0, delta0_frac=0.02,
                     label_quality=ann.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    with TraceStore(trace_path, "obs-noisy-s0") as tr:
        camp.attach_trace(tr)
        if metrics is not None:
            metrics.attach_trace(tr)   # interleave metric events
            camp.attach_metrics(metrics)
        res = camp.run()
        if metrics is not None:
            metrics.emit_snapshot(scope="bench")
        return res


def run_smoke(enforce: bool = True, repeat: int = 4):
    import time

    from repro.obs import MetricsRegistry, cache_hit_rates, set_registry
    from repro.trace import diff

    off_path = artifact_path(TRACE_OFF)
    on_path = artifact_path(TRACE_ON)

    # Run the legs as back-to-back PAIRS (off then on) and gate on the
    # best per-pair ratio: each pair shares the same machine state, so a
    # single quiet pair reveals the true instrumented/plain ratio, and
    # host drift that hits one pair inflates that pair's ratio without
    # polluting the others.  (Separate min-over-leg minima need BOTH
    # minima to land on quiet moments — on a sub-second campaign the
    # scheduler jitter between those moments is itself > the 3% gate.)
    _campaign(off_path)   # warmup: jit compiles land outside the timing
    best = float("inf")
    off_us = on_us = 0.0
    res_off = res_on = None
    last = {}
    for _ in range(repeat):
        t0 = time.perf_counter()
        res_off = _campaign(off_path)
        off = time.perf_counter() - t0
        m = MetricsRegistry()   # fresh per repeat: identical work each run
        last["m"] = m
        t0 = time.perf_counter()
        res_on = _campaign(on_path, m)
        on = time.perf_counter() - t0
        if on / off < best:
            best = on / off
            off_us, on_us = off * 1e6, on * 1e6
    assert res_on.total_cost == res_off.total_cost, \
        "attaching metrics changed the campaign's decisions"
    overhead = best - 1.0

    d = diff(off_path, on_path)
    clean = d is None

    m = last["m"]
    m.write_prometheus(artifact_path(PROM_NAME))
    set_registry(m)   # benchmarks.run embeds get_registry().snapshot()
    snap = m.snapshot()
    n_spans = sum(h["count"] for h in snap["histograms"]
                  if h["name"] == "span_seconds")
    cache = cache_hit_rates(snap)
    rate = {eng: round(c["rate"], 3) for eng, c in sorted(cache.items())}

    if enforce:
        assert clean, (
            f"metrics contaminated the replay stream: {d.describe()}")
        assert overhead <= OVERHEAD_GATE, (
            f"metrics overhead {overhead:.1%} exceeds the "
            f"{OVERHEAD_GATE:.0%} gate "
            f"({on_us:.0f}us instrumented vs {off_us:.0f}us metrics-off)")

    return [
        Row("obs_overhead", on_us,
            f"overhead={overhead:+.1%};gate<={OVERHEAD_GATE:.0%};"
            f"metrics_off_us={off_us:.0f};diff_clean={clean}",
            meta={"overhead": overhead, "pool": POOL,
                  "diff_clean": bool(clean),
                  "artifact": artifact_path(PROM_NAME)}),
        Row("obs_telemetry", on_us,
            f"spans={n_spans};cache_hit_rates={rate}",
            meta={"spans": int(n_spans), "cache_hit_rates": rate}),
    ]


def run():
    """Full-suite leg: same measurement, gates reported but not
    enforced (the smoke leg is the enforcing one)."""
    return run_smoke(enforce=False)


if __name__ == "__main__":
    for r in run_smoke():
        print(r.csv())
