"""Trace-store benchmark: hot-path overhead + replay fidelity.

Two gated claims about the campaign event bus:

* tracing is effectively free on the live path — a fully traced noisy
  adaptive-repeats campaign (every charge, vote round, measurement, fit,
  search, iteration, and commit emitted) must run within 5% of the
  identical untraced campaign (best-of-N wall clock on both legs);
* the trace IS the campaign — replaying it must reproduce the exact
  total cost, iteration count, and decision with zero engine recompute.

The smoke leg leaves its trace at ``artifacts/TRACE_smoke.jsonl`` (see
``common.artifact_dir``) so CI uploads it as a workflow artifact next to
``BENCH_*.json`` without littering the repo root.
"""
from __future__ import annotations

from benchmarks.common import Row, artifact_path, timed, timed_best

OVERHEAD_GATE = 0.05            # traced/untraced - 1, enforced in smoke
TRACE_NAME = "TRACE_smoke.jsonl"
POOL = 20000


def _campaign(trace_path=None):
    """One noisy adaptive-repeats emulated campaign; returns MCALResult.
    Fresh task + annotation service per call (both are stateful)."""
    from repro.annotation import make_annotation_service
    from repro.core import AMAZON, MCALConfig, make_emulated_task
    from repro.core.mcal import MCALCampaign

    ann = make_annotation_service(
        10, noise=0.2, repeats=3, max_repeats=5, adaptive=True,
        aggregator="ds", pricing=AMAZON, seed=0)
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=POOL)
    task.annotation = ann
    cfg = MCALConfig(seed=0, label_quality=ann.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    if trace_path is None:
        return camp.run()
    from repro.trace import TraceStore
    with TraceStore(trace_path, "smoke-noisy-s0") as tr:
        camp.attach_trace(tr)
        return camp.run()


def run_smoke(enforce: bool = True, repeat: int = 3):
    from repro.trace import read_trace, replay

    trace_path = artifact_path(TRACE_NAME)
    res_plain, plain_us = timed_best(_campaign, repeat=repeat)
    res_traced, traced_us = timed_best(_campaign, trace_path,
                                       repeat=repeat)
    assert res_traced.total_cost == res_plain.total_cost, \
        "attaching a trace changed the campaign's decisions"
    overhead = traced_us / plain_us - 1.0

    rp, replay_us = timed(replay, trace_path)
    match = (rp.total_cost == res_traced.total_cost
             and len(rp.history) == len(res_traced.history)
             and rp.decision == res_traced.decision
             and rp.votes == res_traced.ledger["human_votes"])
    if enforce:
        assert match, (
            f"replay diverged from live: ${rp.total_cost} vs "
            f"${res_traced.total_cost}, {len(rp.history)} vs "
            f"{len(res_traced.history)} iterations")
        assert overhead <= OVERHEAD_GATE, (
            f"trace overhead {overhead:.1%} exceeds the "
            f"{OVERHEAD_GATE:.0%} gate "
            f"({traced_us:.0f}us traced vs {plain_us:.0f}us untraced)")

    n_events = len(read_trace(trace_path))
    return [
        Row("trace_overhead", traced_us,
            f"overhead={overhead:+.1%};gate<={OVERHEAD_GATE:.0%};"
            f"untraced_us={plain_us:.0f};events={n_events}",
            meta={"overhead": overhead, "pool": POOL,
                  "events": n_events, "artifact": trace_path}),
        Row("trace_replay", replay_us,
            f"cost=${rp.total_cost:.0f};iters={len(rp.history)};"
            f"votes={rp.votes};replay_match={match}",
            meta={"replay_match": bool(match)}),
    ]


def run():
    """Full-suite leg: same measurement, gates reported but not
    enforced (the smoke leg is the enforcing one)."""
    return run_smoke(enforce=False)


if __name__ == "__main__":
    for r in run_smoke():
        print(r.csv())
