"""Paper Fig. 13: MCAL on CIFAR-10 subsets (1000-5000 samples per class).

With fewer samples per class a larger fraction goes to training, so the
machine-labeled fraction (and the savings) must grow with the subset size.
"""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import AMAZON, MCALConfig, make_emulated_task, run_mcal


def run():
    rows = []
    fracs = {}
    for per_class in (1000, 2000, 3000, 5000):
        pool = per_class * 10
        task = make_emulated_task("cifar10", "resnet18", seed=0,
                                  pool_size=pool)
        res, us = timed(run_mcal, task, AMAZON, MCALConfig(seed=0))
        frac = res.S_size / pool
        fracs[per_class] = frac
        rows.append(Row(
            f"fig13_cifar10_{per_class}pc", us,
            f"S_frac={frac:.2f};cost=${res.total_cost:.0f};"
            f"save={1 - res.total_cost / (pool * 0.04):.1%}"))
    rows.append(Row(
        "fig13_monotone", 0.0,
        f"grows={fracs[5000] > fracs[1000]}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
