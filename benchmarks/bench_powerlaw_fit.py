"""Paper Fig. 2 + Fig. 3 + Appendix F: truncated vs plain power-law fits.

Generates noisy error curves from known truncated power laws (one per
(dataset x model) calibration), fits both families on k-point prefixes and
reports extrapolation error at large |B| — the truncated family must
dominate, and the fit must improve monotonically with more points.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core.emulator import CALIBRATIONS
from repro.core.powerlaw import PowerLaw, fit_power_law


def _curve(alpha, gamma, k, sizes, noise, rng):
    law = PowerLaw(alpha=alpha, gamma=gamma, k=k)
    return law.predict(sizes) * np.exp(rng.normal(0, noise, len(sizes)))


def run():
    rows = []
    rng = np.random.default_rng(0)
    sizes = np.asarray([500, 1000, 2000, 4000, 8000, 16000, 24000, 32000])
    target_B = 40_000

    # Fig. 2: truncated vs plain extrapolation quality
    rel_t, rel_p, t_us = [], [], 0.0
    for (ds, arch), (a, g, k, q, cu) in CALIBRATIONS.items():
        true = PowerLaw(alpha=a, gamma=g, k=k)
        errs = _curve(a, g, k, sizes, 0.05, rng)
        fit_t, us = timed(fit_power_law, sizes, errs, truncated=True)
        t_us += us
        fit_p = fit_power_law(sizes, errs, truncated=False)
        tgt = float(true.predict(target_B))
        rel_t.append(abs(float(fit_t.predict(target_B)) - tgt) / tgt)
        rel_p.append(abs(float(fit_p.predict(target_B)) - tgt) / tgt)
    rows.append(Row("fig2_truncated_fit_relerr", t_us / len(CALIBRATIONS),
                    f"{np.mean(rel_t):.3f}"))
    rows.append(Row("fig2_plain_fit_relerr", t_us / len(CALIBRATIONS),
                    f"{np.mean(rel_p):.3f}"))

    # Fig. 3: error prediction improves with number of estimates
    a, g, k, _, _ = CALIBRATIONS[("cifar10", "resnet18")]
    true = PowerLaw(alpha=a, gamma=g, k=k)
    tgt = float(true.predict(target_B))
    for npts in (3, 5, 8):
        rel = []
        for s in range(16):
            r2 = np.random.default_rng(s)
            errs = _curve(a, g, k, sizes[:npts], 0.05, r2)
            fit = fit_power_law(sizes[:npts], errs, truncated=npts >= 3)
            rel.append(abs(float(fit.predict(target_B)) - tgt) / tgt)
        rows.append(Row(f"fig3_fit_{npts}pts_relerr", t_us / len(CALIBRATIONS),
                        f"{np.mean(rel):.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
