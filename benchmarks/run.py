# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark aggregator — one module per paper table/figure:

  bench_powerlaw_fit       Fig. 2 / Fig. 3 / Appendix F
  bench_delta_sensitivity  Fig. 4
  bench_selection          Fig. 5 / Fig. 6 / Fig. 11
  bench_table1             Tbl. 1 / Fig. 7 (+ arch selection)
  bench_al_sweep           Figs. 8-10 / Fig. 12
  bench_al_gains           §5.2 / Figs. 14-15 (live AL vs random)
  bench_table2             Tbl. 2 (oracle AL)
  bench_subset_sweep       Fig. 13
  bench_table3             Tbl. 3 (eps = 10%)
  bench_imagenet_bailout   §5.1 ImageNet
  bench_kernels            margin_head scoring structure
  bench_sweep              streaming pool-sweep runtime (>= 2x gate)

Run all:  PYTHONPATH=src python -m benchmarks.run
One:      PYTHONPATH=src python -m benchmarks.run --only table1
CI smoke: PYTHONPATH=src python -m benchmarks.run --smoke
          (small-shape sweep + scoring + k-center engine legs, speedup
          gates enforced — the CI matrix runs this on both jax legs)
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = (
    "bench_powerlaw_fit",
    "bench_delta_sensitivity",
    "bench_selection",
    "bench_table1",
    "bench_al_sweep",
    "bench_al_gains",
    "bench_table2",
    "bench_subset_sweep",
    "bench_table3",
    "bench_imagenet_bailout",
    "bench_kernels",
    "bench_sweep",
)


def run_smoke() -> int:
    """The CI smoke leg: small-shape sweep-runtime + engine benchmarks
    with their speedup gates ENFORCED (a gate miss fails the job)."""
    from benchmarks import bench_selection, bench_sweep

    print("name,us_per_call,derived")
    status = 0
    for name, fn in (
        ("bench_sweep[smoke]", bench_sweep.run_smoke),
        ("bench_selection[scoring]",
         lambda: bench_selection.run_scoring(enforce=True)),
        ("bench_selection[kcenter]",
         lambda: bench_selection.run_kcenter(enforce=True)),
    ):
        try:
            for row in fn():
                print(row.csv(), flush=True)
        except Exception as e:
            status = 1
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,ERROR:{type(e).__name__}", flush=True)
    return status


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: sweep + scoring + k-center engine legs "
                         "at small shapes, speedup gates enforced")
    args = ap.parse_args()

    if args.smoke:
        sys.exit(run_smoke())

    print("name,us_per_call,derived")
    failed = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for row in mod.run():
                print(row.csv(), flush=True)
        except Exception as e:
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,ERROR:{type(e).__name__}", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
