# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark aggregator — one module per paper table/figure:

  bench_powerlaw_fit       Fig. 2 / Fig. 3 / Appendix F
  bench_delta_sensitivity  Fig. 4
  bench_selection          Fig. 5 / Fig. 6 / Fig. 11
  bench_table1             Tbl. 1 / Fig. 7 (+ arch selection)
  bench_al_sweep           Figs. 8-10 / Fig. 12
  bench_al_gains           §5.2 / Figs. 14-15 (live AL vs random)
  bench_table2             Tbl. 2 (oracle AL)
  bench_subset_sweep       Fig. 13
  bench_table3             Tbl. 3 (eps = 10%)
  bench_imagenet_bailout   §5.1 ImageNet
  bench_kernels            margin_head scoring structure
  bench_sweep              streaming pool-sweep runtime (>= 2x gate)
  bench_fit                fused retrain engine (>= 2x gate, exact params)
  bench_annotation         device Dawid-Skene EM (>= 2x gate, exact argmax)
  bench_trace              campaign event bus (<= 5% overhead gate +
                           replay-equals-live; smoke leaves its trace
                           under artifacts/ as a CI artifact)
  bench_orchestrator       multi-tenant fleet (0-new-compiles-after-
                           tenant-1 gate + <= 0.75x fresh-serial wall)
  bench_obs                runtime metrics layer (<= 3% overhead gate +
                           metrics-on/off trace diff clean; smoke drops
                           a Prometheus snapshot under artifacts/ and
                           its registry snapshot lands in BENCH_*.json)
  bench_faults             fault-injection harness (chaos run diff-clean
                           vs fault-free sibling + <= 5% idle-injector
                           overhead gate; smoke leaves its chaos trace
                           under artifacts/)
  bench_health             campaign health engine (<= 3% overhead gate:
                           health-monitored noisy campaign vs its
                           monitor-off sibling, decision streams diff
                           clean, same total cost)

Run all:  PYTHONPATH=src python -m benchmarks.run
One:      PYTHONPATH=src python -m benchmarks.run --only table1
CI smoke: PYTHONPATH=src python -m benchmarks.run --smoke
          (small-shape fit + sweep + scoring + k-center + annotation +
          orchestrator engine legs, speedup gates enforced — the CI
          matrix runs this on both jax legs)
History:  PYTHONPATH=src python -m benchmarks.run --check-history
          (the regression observatory: judge every gate's trend across
          benchmarks/history/ and fail on a >30% drop vs the rolling
          baseline — no jax import, see benchmarks/regress.py)

Every invocation additionally writes a machine-readable
``BENCH_<run>.json`` into ``benchmarks/history/`` (``--json`` overrides
the path, ``--run-id`` the stable orderable run name): per-row
us_per_call + parsed per-gate speedups + pool sizes + the jax
version/backend, so the perf trajectory is tracked across PRs — CI
uploads it as a workflow artifact, and the cross-PR trajectory lives
in-tree, not just in CI retention.  The smoke leg ends with a warn-only
observatory pass over that history so drift shows up in every CI log.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import sys
import time
import traceback

MODULES = (
    "bench_powerlaw_fit",
    "bench_delta_sensitivity",
    "bench_selection",
    "bench_table1",
    "bench_al_sweep",
    "bench_al_gains",
    "bench_table2",
    "bench_subset_sweep",
    "bench_table3",
    "bench_imagenet_bailout",
    "bench_kernels",
    "bench_sweep",
    "bench_fit",
    "bench_annotation",
    "bench_trace",
    "bench_orchestrator",
    "bench_obs",
    "bench_faults",
    "bench_health",
)

HISTORY_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "history")


def write_bench_json(path: str, run_id: str, mode: str, rows, errors) -> None:
    """The cross-PR perf-trajectory record: one JSON per benchmark run."""
    import jax

    blob = {
        "run": run_id,
        "mode": mode,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "rows": [r.record() for r in rows],
        "gates": {r.name: r.record()["speedup"] for r in rows
                  if "speedup" in r.record()},
        "errors": errors,
    }
    # the run's telemetry rides along: whatever registry bench_obs (or
    # any other module) installed as the process default
    try:
        from repro.obs import get_registry
        snap = get_registry().snapshot()
        if any(snap.values()):
            blob["metrics"] = snap
    except Exception:
        pass
    with open(path, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"# wrote {path}", file=sys.stderr)


def run_smoke():
    """The CI smoke leg: small-shape fit-engine + sweep-runtime + engine
    benchmarks with their speedup gates ENFORCED (a gate miss fails the
    job).  Returns (status, rows, errors)."""
    from benchmarks import (bench_annotation, bench_faults, bench_fit,
                            bench_health, bench_obs, bench_orchestrator,
                            bench_selection, bench_sweep, bench_trace)

    print("name,us_per_call,derived")
    status, rows, errors = 0, [], []
    for name, fn in (
        ("bench_fit[smoke]", bench_fit.run_smoke),
        ("bench_sweep[smoke]", bench_sweep.run_smoke),
        ("bench_selection[scoring]",
         lambda: bench_selection.run_scoring(enforce=True)),
        ("bench_selection[kcenter]",
         lambda: bench_selection.run_kcenter(enforce=True)),
        ("bench_annotation[smoke]", bench_annotation.run_smoke),
        ("bench_trace[smoke]", bench_trace.run_smoke),
        ("bench_orchestrator[smoke]", bench_orchestrator.run_smoke),
        ("bench_obs[smoke]", bench_obs.run_smoke),
        ("bench_faults[smoke]", bench_faults.run_smoke),
        ("bench_health[smoke]", bench_health.run_smoke),
    ):
        try:
            for row in fn():
                rows.append(row)
                print(row.csv(), flush=True)
        except Exception as e:
            status = 1
            errors.append(f"{name}:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,ERROR:{type(e).__name__}", flush=True)
    # warn-only observatory pass: history drift belongs in every smoke
    # log, but must never fail a PR that didn't touch perf
    try:
        from benchmarks import regress
        report = regress.evaluate(regress.load_history())
        print(regress.render(report), file=sys.stderr)
    except Exception as e:
        print(f"# regress observatory skipped: {e}", file=sys.stderr)
    return status, rows, errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: fit + sweep + scoring + k-center + "
                         "annotation + orchestrator legs at small "
                         "shapes, speedup gates enforced")
    ap.add_argument("--run-id", default="",
                    help="run name for the BENCH_<run>.json record "
                         "(default: the mode + jax version)")
    ap.add_argument("--json", default="",
                    help="path for the machine-readable record "
                         "(default: benchmarks/history/BENCH_<run>.json)")
    ap.add_argument("--check-history", action="store_true",
                    help="run the regression observatory over "
                         "benchmarks/history/ and exit (no benchmarks "
                         "run, no jax import)")
    ap.add_argument("--from-trace", default="", metavar="DIR",
                    help="reproduce paper-table campaign cells from "
                         "stored traces in DIR when present (modules "
                         "that support it replay instead of re-running; "
                         "live cells record their trace there)")
    args = ap.parse_args()

    if args.check_history:
        # the observatory is jax-free by design: judging history must
        # work on a box that can't even import the benchmarks
        from benchmarks import regress
        sys.exit(regress.main([]))

    def finish(mode: str, status: int, rows, errors):
        import jax
        run_id = args.run_id or f"{mode}-jax{jax.__version__}"
        # records ALWAYS land in benchmarks/history/ (stable, orderable
        # run id in the name) — the in-tree trajectory only works if
        # every run contributes to it, not just runs started from the
        # right CWD
        if args.json:
            path = args.json
        else:
            os.makedirs(HISTORY_DIR, exist_ok=True)
            path = os.path.join(HISTORY_DIR, f"BENCH_{run_id}.json")
        write_bench_json(path, run_id, mode, rows, errors)
        sys.exit(status)

    if args.smoke:
        finish("smoke", *run_smoke())

    print("name,us_per_call,derived")
    rows, errors = [], []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            kw = {}
            if args.from_trace and \
                    "trace_dir" in inspect.signature(mod.run).parameters:
                kw["trace_dir"] = args.from_trace
            for row in mod.run(**kw):
                rows.append(row)
                print(row.csv(), flush=True)
        except Exception as e:
            errors.append(f"{name}:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,ERROR:{type(e).__name__}", flush=True)
    finish(args.only or "full", 1 if errors else 0, rows, errors)


if __name__ == "__main__":
    main()
