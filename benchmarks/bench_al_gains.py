"""Paper §5.2 / Figs. 14-15: gains from active learning inside MCAL.

MCAL with uncertainty-ranked acquisition (margin M(.)) vs the same driver
with RANDOM acquisition.  This must run on the LIVE task (a real JAX
classifier): with the emulator, error depends only on |B|, so acquisition
composition cannot matter by construction.  The paper reports ~20-32%
gains for Fashion/CIFAR-10-difficulty datasets.
"""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import AMAZON, LiveTask, MCALConfig, run_mcal
from repro.data.synth import make_classification


def _task(seed):
    x, y = make_classification(4000, num_classes=10, dim=32,
                               difficulty=0.35, hard_frac=0.25, seed=seed)
    return LiveTask(features=x, groundtruth=y, num_classes=10, epochs=30,
                    c_u_nominal=2e-4, seed=seed)


def run():
    rows = []
    cfg = dict(seed=0, delta0_frac=0.02, max_iters=25)
    al, us = timed(run_mcal, _task(0), AMAZON,
                   MCALConfig(metric="margin", **cfg))
    rnd = run_mcal(_task(0), AMAZON, MCALConfig(metric="random", **cfg))
    gain = 1.0 - al.total_cost / rnd.total_cost
    rows.append(Row(
        "fig14_15_live_al_gain", us,
        f"al=${al.total_cost:.0f};random=${rnd.total_cost:.0f};"
        f"al_gain={gain:.1%};al_S={al.S_size};rnd_S={rnd.S_size}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
