"""Kernel-level benchmark: margin_head fused scoring vs the two-pass
reference (materialize logits -> top-k/logsumexp).

On this CPU container the Pallas kernel runs in interpret mode (not
representative), so the timed numbers are the jnp reference vs the
jnp online-chunked twin — the HBM-traffic structure (O(T*V) vs O(T*D)) is
what transfers to TPU; correctness of the Pallas kernel itself is covered
by the allclose sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed
from repro.models.layers import chunked_score_stats, score_stats_from_logits


def run():
    rows = []
    rng = np.random.default_rng(0)
    T, D, V = 512, 512, 32_000
    h = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, V)) * 0.05, jnp.float32)

    ref = jax.jit(lambda h, w: score_stats_from_logits(
        jnp.einsum("td,dv->tv", h, w)))
    fused = jax.jit(lambda h, w: chunked_score_stats(h, w, chunk=4096))
    jax.block_until_ready(ref(h, w))
    jax.block_until_ready(fused(h, w))

    _, us_ref = timed(lambda: jax.block_until_ready(ref(h, w)), repeat=5)
    _, us_fused = timed(lambda: jax.block_until_ready(fused(h, w)), repeat=5)
    a, b = ref(h, w), fused(h, w)
    ok = np.allclose(np.asarray(a.margin), np.asarray(b.margin), atol=1e-3)
    rows.append(Row("margin_head_ref_materialized", us_ref,
                    f"T={T};V={V}"))
    rows.append(Row("margin_head_online_chunked", us_fused,
                    f"match={ok};hbm_ratio~{V / D:.0f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
