"""Shared benchmark plumbing: timing + CSV rows.

Every benchmark module exposes ``run() -> list[Row]``; ``benchmarks.run``
aggregates them into the ``name,us_per_call,derived`` CSV the harness
expects (us_per_call times the benchmark's core computation; ``derived``
carries the headline metric the paper table/figure reports).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def timed_best(fn: Callable, *args, repeat: int = 3, **kw):
    """Best-of-N wall time (us).  For enforced speedup gates: min-over-runs
    suppresses co-tenant CI noise symmetrically on both legs, where a mean
    lets one slow outlier flip a hard floor."""
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6
