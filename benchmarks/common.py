"""Shared benchmark plumbing: timing + CSV rows + the trace-aware MCAL
cell runner.

Every benchmark module exposes ``run() -> list[Row]``; ``benchmarks.run``
aggregates them into the ``name,us_per_call,derived`` CSV the harness
expects (us_per_call times the benchmark's core computation; ``derived``
carries the headline metric the paper table/figure reports).

Paper-table modules (``bench_table{1,2,3}``) drive their campaign cells
through :func:`mcal_cell`, which accepts a ``--from-trace DIR``: a cell
whose trace exists under the directory is REPRODUCED from the trace
alone (replay, zero engine recompute); otherwise the cell runs live —
and when the directory is set, the live run also writes its trace there
and asserts the replayed totals match the live ones before reporting.
"""
from __future__ import annotations

import dataclasses
import os
import re
import time
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    # optional machine-readable payload (pool sizes, enforced speedups,
    # ...) carried into the BENCH_<run>.json trajectory file
    meta: Optional[Dict] = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"

    def record(self) -> Dict:
        """The row as a JSON-ready dict: explicit ``meta`` merged over
        whatever ``speedup=<x>x`` figure the derived column carries, so
        benchmarks that predate ``meta`` still land in the trajectory."""
        out = {"name": self.name, "us_per_call": round(self.us_per_call, 1),
               "derived": self.derived}
        m = re.search(r"speedup=([0-9.]+)x", self.derived)
        if m:
            out["speedup"] = float(m.group(1))
        if self.meta:
            out.update(self.meta)
        return out


def artifact_dir() -> str:
    """Where benchmark runs drop their non-CSV byproducts (smoke traces,
    metrics snapshots, profiles) — ``$BENCH_ARTIFACT_DIR`` or
    ``artifacts/`` in the CWD, created on first use.  Keeping them in one
    gitignored directory means CI uploads a single path and nothing
    strays into the repo root."""
    d = os.environ.get("BENCH_ARTIFACT_DIR", "artifacts")
    os.makedirs(d, exist_ok=True)
    return d


def artifact_path(name: str) -> str:
    return os.path.join(artifact_dir(), name)


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def timed_best(fn: Callable, *args, repeat: int = 3, **kw):
    """Best-of-N wall time (us).  For enforced speedup gates: min-over-runs
    suppresses co-tenant CI noise symmetrically on both legs, where a mean
    lets one slow outlier flip a hard floor."""
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def mcal_cell(name: str, make_task: Callable, service, cfg, *,
              trace_dir: Optional[str] = None) -> Tuple[object, float, str]:
    """Run one paper-table MCAL cell, trace-aware.  Returns
    ``(MCALResult, us, source)`` where source is ``"replay"`` (cell
    reproduced from ``trace_dir/<name>.jsonl`` with zero engine
    recompute) or ``"live"``.  A live run with ``trace_dir`` set writes
    its trace there and asserts the replayed totals match the live
    result before returning — every stored table cell is replay-verified
    at creation."""
    from repro.trace import replay
    path = os.path.join(trace_dir, f"{name}.jsonl") if trace_dir else None
    if path and os.path.exists(path):
        rp, us = timed(replay, path)
        if rp.result is None:
            raise AssertionError(
                f"{name}: stored trace {path} has no commit event — "
                f"a preempted campaign cannot reproduce a table cell")
        return rp.result, us, "replay"

    from repro.core.mcal import MCALCampaign

    def live():
        camp = MCALCampaign(make_task(), service, cfg)
        if path:
            from repro.trace import TraceStore
            os.makedirs(trace_dir, exist_ok=True)
            with TraceStore(path, name) as tr:
                camp.attach_trace(tr)
                return camp.run()
        return camp.run()

    res, us = timed(live)
    if path:
        rp = replay(path)
        if rp.total_cost != res.total_cost or \
                len(rp.history) != len(res.history):
            raise AssertionError(
                f"{name}: replayed trace diverges from the live run "
                f"(cost ${rp.total_cost} vs ${res.total_cost}, "
                f"{len(rp.history)} vs {len(res.history)} iterations)")
    return res, us, "live"


def add_trace_arg(ap) -> None:
    """The table modules' shared ``--from-trace DIR`` flag."""
    ap.add_argument("--from-trace", default=None, metavar="DIR",
                    help="reproduce campaign cells from stored traces in "
                         "DIR when present; run live (and record the "
                         "trace there, replay-verified) otherwise")
