"""Shared benchmark plumbing: timing + CSV rows.

Every benchmark module exposes ``run() -> list[Row]``; ``benchmarks.run``
aggregates them into the ``name,us_per_call,derived`` CSV the harness
expects (us_per_call times the benchmark's core computation; ``derived``
carries the headline metric the paper table/figure reports).
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    # optional machine-readable payload (pool sizes, enforced speedups,
    # ...) carried into the BENCH_<run>.json trajectory file
    meta: Optional[Dict] = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"

    def record(self) -> Dict:
        """The row as a JSON-ready dict: explicit ``meta`` merged over
        whatever ``speedup=<x>x`` figure the derived column carries, so
        benchmarks that predate ``meta`` still land in the trajectory."""
        out = {"name": self.name, "us_per_call": round(self.us_per_call, 1),
               "derived": self.derived}
        m = re.search(r"speedup=([0-9.]+)x", self.derived)
        if m:
            out["speedup"] = float(m.group(1))
        if self.meta:
            out.update(self.meta)
        return out


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def timed_best(fn: Callable, *args, repeat: int = 3, **kw):
    """Best-of-N wall time (us).  For enforced speedup gates: min-over-runs
    suppresses co-tenant CI noise symmetrically on both legs, where a mean
    lets one slow outlier flip a hard floor."""
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6
