"""Paper Fig. 4: eps(S^theta) depends only weakly on delta once |B| is
large — MCAL exploits this to grow delta late in the campaign.

We measure eps_theta at fixed |B| = 16k reached with different deltas on
the CIFAR-10/Res18 emulated task; the spread across deltas must be small
(< 1% absolute for small theta, per the paper).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core import make_emulated_task
from repro.core.selection import machine_label_error_curve


def _eps_at_B(task, B, thetas, seed=0):
    rng = np.random.default_rng(seed)
    T_idx = rng.choice(task.pool_size, 2500, replace=False)
    idx = rng.choice(np.setdiff1d(np.arange(task.pool_size), T_idx), B,
                     replace=False)
    task.train(idx, task.human_label(idx))
    stats, _ = task.score(T_idx)
    correct = task.eval_correct(T_idx, task.human_label(T_idx))
    return machine_label_error_curve(stats, correct, thetas)


def run():
    thetas = [0.2, 0.5, 0.8]
    curves = {}
    us = 0.0
    # growing to 16k in different-size steps => different acquisition
    # schedules; the emulated classifier error depends only on |B|
    # plus the per-(seed, B) measurement draw — like Fig. 4's finding.
    for delta_frac, seed in ((0.01, 1), (0.05, 2), (0.15, 3)):
        task = make_emulated_task("cifar10", "resnet18", seed=seed)
        c, dt = timed(_eps_at_B, task, 16_000, thetas, seed)
        us += dt
        curves[delta_frac] = c
    spread = np.max([np.abs(curves[a] - curves[b])
                     for a in curves for b in curves], axis=0)
    rows = [Row("fig4_eps_theta_delta_spread", us / 3,
                ";".join(f"th{t}={s:.4f}" for t, s in zip(thetas, spread)))]
    rows.append(Row("fig4_small_theta_spread_lt_1pct", 0.0,
                    f"{spread[0] < 0.01}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
