"""Paper §5.1 (ImageNet/EfficientNet-B0): on a dataset too hard/expensive
to machine-label, MCAL must bail out to human-labeling everything after a
bounded exploration tax (x = 10% of the human-labeling cost)."""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import AMAZON, MCALConfig, make_emulated_task, run_mcal


def run():
    task = make_emulated_task("imagenet", "efficientnet-b0", seed=0)
    res, us = timed(run_mcal, task, AMAZON, MCALConfig(seed=0))
    human_all = task.pool_size * AMAZON.price_per_label
    tax = res.ledger["training"]
    return [
        Row("imagenet_bailout", us,
            f"decision={res.decision};tax=${tax:.0f};"
            f"tax_frac={tax / human_all:.3f};"
            f"explored_B={res.B_size};err={res.measured_error:.4f}"),
        Row("imagenet_bailout_bounded", 0.0,
            f"{res.decision == 'human_all' and tax <= 0.15 * human_all}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
