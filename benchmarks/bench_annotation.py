"""Device Dawid-Skene EM vs the host NumPy reference loop.

Every human-label purchase in a noisy-oracle campaign aggregates an
(items, workers) vote matrix, and adaptive-repeats policies re-aggregate
once per top-up round — at paper scale (50k-item acquisition batches,
5-worker pools) the aggregation is a real hot path.  Two implementations
of one EM:

  ds_host     ``aggregate.dawid_skene_host``: the float64 NumPy
              reference (per-worker python loop per EM iteration) — the
              exact-agreement oracle the device engine is validated
              against;
  ds_device   ``VoteAggregator.dawid_skene``: the whole EM as ONE
              jit-compiled program (``lax.fori_loop`` over M-then-E
              iterations, items padded through ``scoring.pack_shape``'s
              pow2 bucketing).

``--enforce`` (the CI gate) asserts IDENTICAL argmax labels + atol-
bounded posteriors AND >= 2x for the device program at the gate shape
(50k x 5).  Majority vote is reported alongside (exact agreement
asserted) but not gated — it is too cheap on both sides to gate
meaningfully.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed_best


def _vote_matrix(n: int, workers: int, classes: int, repeats: int,
                 seed: int = 0):
    from repro.annotation import make_annotator_pool

    pool = make_annotator_pool(workers, classes, noise=0.25,
                               spammer_frac=0.2, seed=seed)
    rng = np.random.default_rng(seed + 1)
    gt = rng.integers(0, classes, n)
    # the service's own worker schedule: the benchmark measures the
    # exact matrices campaigns aggregate
    return pool.vote_matrix(np.arange(n), gt, repeats)


def run_ds(grid=((5_000, 5, 10, 3), (50_000, 5, 10, 3)),
           gate_shape=(50_000, 5), enforce: bool = False) -> list:
    from repro.annotation import (VoteAggregator, dawid_skene_host,
                                  majority_vote_host)

    rows, gate_speedup = [], None
    for n, workers, classes, repeats in grid:
        votes = _vote_matrix(n, workers, classes, repeats)
        agg = VoteAggregator(classes)

        dev, us_dev = timed_best(lambda: agg.dawid_skene(votes), repeat=3)
        ref, us_host = timed_best(
            lambda: dawid_skene_host(votes, classes), repeat=2)
        # agreement asserted on every shape, not just the gate
        assert np.array_equal(ref.labels, dev.labels), \
            f"device EM argmax diverged from the host EM at (n={n})"
        assert np.max(np.abs(ref.posterior - dev.posterior)) < 1e-3, \
            f"device EM posteriors off the host EM at (n={n})"
        speedup = us_host / us_dev
        rows.append(Row(
            f"ds_em_{n}x{workers}_c{classes}", us_dev,
            f"speedup={speedup:.2f}x_vs_hostloop;host_us={us_host:.0f};"
            f"argmax_exact=True",
            meta={"items": n, "workers": workers, "classes": classes,
                  "repeats": repeats, "speedup": round(speedup, 3)}))
        if (n, workers) == gate_shape:
            gate_speedup = speedup

        lm_d, _ = agg.majority(votes)
        lm_h, _ = majority_vote_host(votes, classes)
        assert np.array_equal(lm_d, lm_h), \
            f"device majority diverged from host at (n={n})"

    if enforce:
        assert gate_speedup is not None, \
            f"gate shape {gate_shape} missing from the grid"
        assert gate_speedup >= 2.0, \
            f"device Dawid-Skene only {gate_speedup:.2f}x over the host " \
            f"reference at {gate_shape}"
    return rows


def run_smoke() -> list:
    """CI smoke: a small warm-up shape plus the acceptance gate shape
    (50k items x 5 workers), agreement + the >= 2x floor enforced."""
    return run_ds(enforce=True)


def run() -> list:
    return run_ds(
        grid=((5_000, 5, 10, 3), (50_000, 5, 10, 3), (50_000, 9, 100, 5)),
        enforce=True)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--enforce", action="store_true",
                    help="assert the >= 2x speedup floor (the CI gate)")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for r in (run_smoke() if args.smoke else run_ds(enforce=args.enforce)):
        print(r.csv())
