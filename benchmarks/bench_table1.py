"""Paper Tbl. 1 + Fig. 7: MCAL total cost vs full human labeling, both
services, with architecture selection (the "DNN Selected" column).

Paper numbers (Amazon): fashion $400/86%, cifar10 $792/67%,
cifar100 $1698/29%; Res18 selected everywhere.

Campaign cells run through ``common.mcal_cell`` — with ``--from-trace
DIR`` they are reproduced from stored traces (replay, no recompute)
when present; the architecture-selection rows drive several coupled
campaigns over a shared pool and always run live.
"""
from __future__ import annotations

from benchmarks.common import Row, add_trace_arg, mcal_cell, timed
from repro.core import (AMAZON, SATYAM, MCALConfig, make_emulated_task,
                        select_architecture)
from repro.core.emulator import DATASETS

PAPER = {  # (service, dataset) -> (cost, savings)
    ("amazon", "fashion"): (400, 0.86),
    ("amazon", "cifar10"): (792, 0.67),
    ("amazon", "cifar100"): (1698, 0.29),
    ("satyam", "fashion"): (29, 0.86),
    ("satyam", "cifar10"): (63, 0.65),
    ("satyam", "cifar100"): (139, 0.23),
}


def run(trace_dir=None):
    rows = []
    for service in (AMAZON, SATYAM):
        for ds in ("fashion", "cifar10", "cifar100"):
            res, us, src = mcal_cell(
                f"tbl1_{service.name}_{ds}",
                lambda ds=ds: make_emulated_task(ds, "resnet18", seed=0),
                service, MCALConfig(seed=0), trace_dir=trace_dir)
            full = DATASETS[ds]["full"] * service.price_per_label
            save = 1 - res.total_cost / full
            p_cost, p_save = PAPER[(service.name, ds)]
            rows.append(Row(
                f"tbl1_{service.name}_{ds}", us,
                f"cost=${res.total_cost:.0f};save={save:.1%};"
                f"err={res.measured_error:.3f};paper=${p_cost}/{p_save:.0%}",
                meta={"source": src}))

    # arch selection (Fig. 7 bars / "DNN Selected") — several campaigns
    # coupled through one shared pool: always live
    for ds in ("fashion", "cifar10", "cifar100"):
        tasks = {a: make_emulated_task(ds, a, seed=0)
                 for a in ("cnn18", "resnet18", "resnet50")}
        (winner, res, _), us = timed(
            select_architecture, tasks, AMAZON, MCALConfig(seed=0))
        rows.append(Row(
            f"tbl1_archsel_{ds}", us,
            f"winner={winner};cost=${res.total_cost:.0f};"
            f"err={res.measured_error:.3f}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    add_trace_arg(ap)
    for r in run(trace_dir=ap.parse_args().from_trace):
        print(r.csv())
