"""Health-engine benchmark: monitoring overhead + replay cleanliness.

Two gated claims about the campaign health engine (``repro.obs.health``):

* judgment is effectively free on the live path — a health-monitored
  noisy adaptive-repeats campaign (full detector suite + an SLO spec
  evaluated every iteration, alert events interleaved into the campaign
  trace) must run within 3% of the identical monitor-off campaign
  (best per back-to-back pair, the ``bench_obs`` convention);
* judgment never contaminates the decision record — ``trace.diff``
  between the monitored and monitor-off sibling traces must be clean
  (``alert``/``alert_clear``/``slo_breach`` are observability kinds;
  the replay stream is byte-identical) and both campaigns must commit
  at the same total cost.

The SLO spec is deliberately breachable (a cost-per-label ceiling the
noisy campaign blows through) so the gate times the engine actually
emitting, not an idle pass.  The smoke leg leaves the monitored trace
under artifacts/ for ``report --health`` spelunking.
"""
from __future__ import annotations

from benchmarks.common import Row, artifact_path

OVERHEAD_GATE = 0.03            # monitored/plain - 1, enforced in smoke
POOL = 20000
TRACE_OFF = "HEALTH_monitor_off.jsonl"
TRACE_ON = "HEALTH_monitor_on.jsonl"


def _campaign(trace_path, health=None):
    """One noisy adaptive-repeats emulated campaign, traced; optionally
    health-monitored.  Fresh task + annotation service per call (both
    are stateful)."""
    from repro.annotation import make_annotation_service
    from repro.core import AMAZON, MCALConfig, make_emulated_task
    from repro.core.mcal import MCALCampaign
    from repro.trace import TraceStore

    ann = make_annotation_service(
        10, noise=0.2, repeats=3, max_repeats=5, adaptive=True,
        aggregator="ds", pricing=AMAZON, seed=0)
    task = make_emulated_task("cifar10", "resnet18", seed=0,
                              pool_size=POOL)
    task.annotation = ann
    # fine delta schedule -> ~17 iterations = ~17 health ticks: enough
    # judgment work that the 3% gate measures the engine, not jitter
    cfg = MCALConfig(seed=0, delta0_frac=0.02,
                     label_quality=ann.expected_quality())
    camp = MCALCampaign(task, AMAZON, cfg)
    with TraceStore(trace_path, "health-noisy-s0") as tr:
        camp.attach_trace(tr)
        if health is not None:
            camp.attach_health(health)   # picks up the trace
        return camp.run()


def _engine():
    """A fresh judge per repeat: full detector suite plus an SLO the
    noisy campaign actually breaches (votes make cost-per-label blow a
    2-cent ceiling), so alert emission is on the timed path."""
    from repro.obs import HealthEngine, SLOSpec
    return HealthEngine(SLOSpec.from_dict({"cost_per_label_max": 0.02}))


def run_smoke(enforce: bool = True, repeat: int = 4):
    import time

    from repro.trace import diff

    off_path = artifact_path(TRACE_OFF)
    on_path = artifact_path(TRACE_ON)

    # back-to-back pairs, best per-pair ratio — see bench_obs for why
    # separate per-leg minima can't resolve a 3% gate on a sub-second
    # campaign
    _campaign(off_path)   # warmup: jit compiles land outside the timing
    best = float("inf")
    off_us = on_us = 0.0
    res_off = res_on = None
    last = {}
    for _ in range(repeat):
        t0 = time.perf_counter()
        res_off = _campaign(off_path)
        off = time.perf_counter() - t0
        h = _engine()
        last["h"] = h
        t0 = time.perf_counter()
        res_on = _campaign(on_path, h)
        on = time.perf_counter() - t0
        if on / off < best:
            best = on / off
            off_us, on_us = off * 1e6, on * 1e6
    assert res_on.total_cost == res_off.total_cost, \
        "attaching the health engine changed the campaign's decisions"
    overhead = best - 1.0

    d = diff(off_path, on_path)
    clean = d is None

    h = last["h"]
    counts = h.counts()
    assert counts["alerts_raised"] > 0, (
        "the breachable SLO never fired — the gate timed an idle judge")

    if enforce:
        assert clean, (
            f"health events contaminated the replay stream: "
            f"{d.describe()}")
        assert overhead <= OVERHEAD_GATE, (
            f"health overhead {overhead:.1%} exceeds the "
            f"{OVERHEAD_GATE:.0%} gate "
            f"({on_us:.0f}us monitored vs {off_us:.0f}us monitor-off)")

    return [
        Row("health_overhead", on_us,
            f"overhead={overhead:+.1%};gate<={OVERHEAD_GATE:.0%};"
            f"monitor_off_us={off_us:.0f};diff_clean={clean}",
            meta={"overhead": overhead, "pool": POOL,
                  "diff_clean": bool(clean),
                  "artifact": on_path}),
        Row("health_judgment", on_us,
            f"ticks={counts['ticks']};raised={counts['alerts_raised']};"
            f"cleared={counts['alerts_cleared']};"
            f"slo_breaches={counts['slo_breaches']}",
            meta=dict(counts)),
    ]


def run():
    """Full-suite leg: same measurement, gates reported but not
    enforced (the smoke leg is the enforcing one)."""
    return run_smoke(enforce=False)


if __name__ == "__main__":
    for r in run_smoke():
        print(r.csv())
