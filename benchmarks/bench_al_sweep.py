"""Paper Figs. 8-10 (+16-18) and Fig. 12: naive AL over delta vs MCAL.

For each dataset: sweep AL batch size delta in [1%, 20%], record total
cost (Fig. 8-10) and machine-labeled fraction (Fig. 12); MCAL must beat
the best (oracle) delta.  Also reports the delta-sensitivity claims:
cost varies multiple-x across delta while the machine-labeled fraction
falls as delta grows.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core import AMAZON, MCALConfig, make_emulated_task, run_mcal
from repro.core.baselines import run_naive_al

DELTAS = (0.01, 0.033, 0.067, 0.10, 0.167, 0.20)


def run():
    rows = []
    for ds in ("fashion", "cifar10", "cifar100"):
        al = {}
        us_total = 0.0
        for d in DELTAS:
            task = make_emulated_task(ds, "resnet18", seed=0)
            res, us = timed(run_naive_al, task, AMAZON, d)
            us_total += us
            al[d] = res
        best = min(al, key=lambda d: al[d].cost)
        worst = max(al, key=lambda d: al[d].cost)
        task = make_emulated_task(ds, "resnet18", seed=0)
        mcal = run_mcal(task, AMAZON, MCALConfig(seed=0))
        rows.append(Row(
            f"fig8_10_{ds}_oracle_al", us_total / len(DELTAS),
            f"best_delta={best};al=${al[best].cost:.0f};"
            f"worst=${al[worst].cost:.0f};mcal=${mcal.total_cost:.0f};"
            f"mcal_wins={mcal.total_cost < al[best].cost}"))
        rows.append(Row(
            f"fig12_{ds}_machine_frac", us_total / len(DELTAS),
            f"d1%={al[0.01].machine_fraction:.2f};"
            f"d20%={al[0.20].machine_fraction:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
