# Benchmark-regression observatory.  jax-free: reads history JSON only.
"""Cross-PR perf-trajectory watchdog over ``benchmarks/history/``.

Every benchmark run writes a ``BENCH_<run>.json`` record (per-gate
speedups + jax version/backend); each PR checks one into
``benchmarks/history/``, so the in-tree trajectory is the series of
gate values across PRs.  This module is the judge over that series:

  PYTHONPATH=src python -m benchmarks.regress
  PYTHONPATH=src python -m benchmarks.regress --json
  PYTHONPATH=src python -m benchmarks.run --check-history   # same thing

For every gate that appears in the newest record, the baseline is the
**median of up to the last ``--window`` prior values** of that gate
(median, not mean — one anomalously fast CI run must not inflate the
bar; missing-in-some-PRs gates simply have shorter series).  The
verdict per gate is latest/baseline:

  ratio <  --fail-under (0.70)   FAIL  — the gate lost >30% vs trend
  ratio <  --warn-under (0.90)   WARN  — drifting down, not yet broken
  otherwise                      ok    (``new`` when no prior exists)

Speedup gates are ratios-vs-host already, so they are machine-portable
enough to compare across PR records from the same CI class; the
fail bar is deliberately loose (0.70) because CI noise on small smoke
shapes is real — the observatory exists to catch step-function
regressions (a fused kernel silently falling back to the host loop),
not 5% jitter.

Exit status: 1 if any gate FAILs (``--warn-only`` downgrades that to
0 — the smoke log rides this mode so history drift is visible without
blocking an unrelated PR).  No jax import anywhere on this path.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional

HISTORY_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "history")
FAIL_UNDER = 0.70
WARN_UNDER = 0.90
BASELINE_WINDOW = 4


def load_history(history_dir: str = HISTORY_DIR) -> List[Dict]:
    """Every ``BENCH_*.json`` under ``history_dir``, oldest first.

    Records are ordered by ``(timestamp, run)`` — the timestamp is the
    authoritative axis (run ids are stable and orderable within one
    naming scheme, but the scheme may change); the run id breaks
    same-second ties deterministically."""
    records = []
    for path in glob.glob(os.path.join(history_dir, "BENCH_*.json")):
        with open(path) as f:
            blob = json.load(f)
        blob["_path"] = path
        records.append(blob)
    records.sort(key=lambda b: (b.get("timestamp", ""), b.get("run", "")))
    return records


def gate_series(records: List[Dict]) -> Dict[str, List[Dict]]:
    """Per-gate value series across the (ordered) records."""
    series: Dict[str, List[Dict]] = {}
    for rec in records:
        for gate, value in (rec.get("gates") or {}).items():
            if value is None:
                continue
            series.setdefault(gate, []).append(
                {"run": rec.get("run", "?"), "value": float(value)})
    return series


def _median(values: List[float]) -> float:
    vs = sorted(values)
    n = len(vs)
    return vs[n // 2] if n % 2 else 0.5 * (vs[n // 2 - 1] + vs[n // 2])


def evaluate(records: List[Dict], *, fail_under: float = FAIL_UNDER,
             warn_under: float = WARN_UNDER,
             window: int = BASELINE_WINDOW) -> Dict:
    """The regression report: one verdict per gate in the newest record,
    judged against the rolling-median baseline of its prior values."""
    report: Dict = {"records": len(records), "gates": [], "status": "ok",
                    "latest_run": (records[-1].get("run")
                                   if records else None)}
    if len(records) < 2:
        report["status"] = "insufficient-history"
        return report
    series = gate_series(records)
    latest_run = records[-1].get("run", "?")
    worst = "ok"
    for gate in sorted(series):
        points = series[gate]
        if points[-1]["run"] != latest_run:
            # gate dropped out of the newest record: trend still shown,
            # but a missing gate is its own kind of signal
            report["gates"].append({
                "gate": gate, "verdict": "missing", "latest": None,
                "baseline": _median([p["value"] for p in points[-window:]]),
                "ratio": None, "last_seen": points[-1]["run"],
                "series": points})
            continue
        latest = points[-1]["value"]
        prior = [p["value"] for p in points[:-1]][-window:]
        if not prior:
            report["gates"].append({
                "gate": gate, "verdict": "new", "latest": latest,
                "baseline": None, "ratio": None, "series": points})
            continue
        baseline = _median(prior)
        ratio = latest / baseline if baseline > 0 else None
        if ratio is None:
            verdict = "new"
        elif ratio < fail_under:
            verdict = "fail"
        elif ratio < warn_under:
            verdict = "warn"
        else:
            verdict = "ok"
        if verdict == "fail" or (verdict == "warn" and worst != "fail"):
            worst = verdict
        report["gates"].append({
            "gate": gate, "verdict": verdict, "latest": latest,
            "baseline": baseline, "ratio": ratio, "series": points})
    report["status"] = worst
    return report


def render(report: Dict) -> str:
    """The terminal view of one :func:`evaluate` pass."""
    lines = [f"# regression observatory: {report['records']} records, "
             f"latest={report['latest_run']}  [{report['status']}]"]
    if report["status"] == "insufficient-history":
        lines.append("# need >= 2 history records to judge a trend")
        return "\n".join(lines)
    mark = {"ok": " ", "new": "+", "warn": "~", "fail": "!",
            "missing": "?"}
    lines.append(f"{'':1} {'gate':<34} {'latest':>8} {'baseline':>9} "
                 f"{'ratio':>7}  trend")
    for g in report["gates"]:
        trend = " -> ".join(f"{p['value']:g}" for p in g["series"][-5:])
        latest = f"{g['latest']:.3f}" if g["latest"] is not None else "-"
        base = (f"{g['baseline']:.3f}" if g["baseline"] is not None
                else "-")
        ratio = f"{g['ratio']:.3f}" if g["ratio"] is not None else "-"
        lines.append(f"{mark[g['verdict']]:1} {g['gate']:<34} "
                     f"{latest:>8} {base:>9} {ratio:>7}  {trend}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="judge benchmark gate trends across the in-tree "
                    "BENCH_*.json history")
    ap.add_argument("--history", default=HISTORY_DIR, metavar="DIR",
                    help="history directory (default: benchmarks/history)")
    ap.add_argument("--fail-under", type=float, default=FAIL_UNDER)
    ap.add_argument("--warn-under", type=float, default=WARN_UNDER)
    ap.add_argument("--window", type=int, default=BASELINE_WINDOW,
                    help="rolling baseline width (median of up to N "
                         "prior values per gate)")
    ap.add_argument("--json", action="store_true",
                    help="print the full report as JSON")
    ap.add_argument("--warn-only", action="store_true",
                    help="always exit 0 (the smoke log's advisory mode)")
    args = ap.parse_args(argv)
    report = evaluate(load_history(args.history),
                      fail_under=args.fail_under,
                      warn_under=args.warn_under, window=args.window)
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print(render(report))
    if args.warn_only:
        return 0
    return 1 if report["status"] == "fail" else 0


if __name__ == "__main__":
    sys.exit(main())
