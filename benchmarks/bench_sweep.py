"""Streaming pool-sweep runtime vs the non-overlapped per-batch loop.

MCAL's commit step is one L(.) pass over the whole remaining pool:
rank most-confident-first + take the top1 machine labels.  Three
implementations of that deliverable at a 200k-row pool:

  sweep_hostloop   the non-overlapped per-batch loop the seed shipped
                   (``score_pool_reference``: chunked forward, one
                   host-blocking round-trip per batch, numpy statistics)
                   + host ranking — the oracle baseline, and the leg the
                   CI gate measures the runtime against;
  sweep_blocking   the same jit-compiled engine step swept page-by-page
                   but host-SYNCED each page (full ScoreStats + feature
                   materialization per page, the pre-sweep
                   ``task.score``-per-chunk pattern) — isolates what
                   double-buffering + sink folding buy over a loop that
                   is already jit-backed;
  sweep_runner     ``PoolSweepRunner`` + ``RankTop1Sink``: paged,
                   double-buffered, sink-folded — one score field + top1
                   per row is all that reaches the host.

The runner must agree with sweep_blocking EXACTLY (identical page
packing -> bit-equal per-row statistics -> identical stable rank) and
with the seed loop to fp tolerance; ``--enforce`` (the CI gate) asserts
the runner is >= 2x faster than the non-overlapped per-batch loop.

A top-k M(.) acquisition row rides along: the device top-k reservoir
sweep vs the same host loop + argpartition.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed_best
from repro.core import selection as sel
from repro.core.scoring import (PoolScoringEngine, ScoringConfig,
                                score_pool_reference)


def _setup(pool: int, dim: int, classes: int):
    import jax
    from repro.configs.base import ModelConfig
    from repro.models.registry import get_model

    cfg = ModelConfig(name="bench-sweep", family="mlp", num_layers=2,
                      d_model=64, num_classes=classes, input_dim=dim,
                      dtype="float32", remat="none")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    x = np.random.default_rng(0).normal(size=(pool, dim)).astype(np.float32)
    return model, params, x


def _hostloop_rank(model, params, x, chunk: int = 2048):
    """The seed's non-overlapped per-batch loop, producing the commit
    deliverable: (order most-confident-first, top1 labels)."""
    stats, _ = score_pool_reference(model, params, x, chunk=chunk)
    return (sel.rank_for_machine_labeling(stats, "margin"),
            np.asarray(stats.top1, np.int64))


def _blocking_rank(engine, params, x, page: int):
    """The jit-engine swept page-by-page with a host sync + full stats and
    feature materialization per page (the pre-sweep per-chunk pattern)."""
    fields, top1 = [], []
    n = x.shape[0]
    for lo in range(0, n, page):
        stats, _feats = engine.score_host(params, x[lo:lo + page])
        fields.append(stats.margin)
        top1.append(np.asarray(stats.top1, np.int64))
    scores = -np.concatenate(fields).astype(np.float64)
    return np.argsort(scores, kind="stable"), np.concatenate(top1)


def run_sweep(pool: int = 200_000, dim: int = 32, classes: int = 10,
              microbatch: int = 2048, page: int = 16_384,
              enforce: bool = False) -> list:
    from repro.serving.sweep import (EngineSweepAdapter, PoolSweepRunner,
                                     RankTop1Sink, SweepConfig, TopKSink)

    model, params, x = _setup(pool, dim, classes)
    engine = PoolScoringEngine(model, ScoringConfig(microbatch=microbatch))
    runner = PoolSweepRunner(EngineSweepAdapter(engine),
                             SweepConfig(page_rows=page))

    # warm every leg (incl. each one's ragged-tail program)
    tail = pool % page or page
    runner.run(params, x[:page + tail], RankTop1Sink("margin"))
    _blocking_rank(engine, params, x[:page + tail], page)
    ref_tail = pool % 2048 or 2048
    score_pool_reference(model, params, x[:2048 + ref_tail])

    (order_host, top1_host), us_host = timed_best(
        _hostloop_rank, model, params, x, repeat=2)
    (order_blk, top1_blk), us_blk = timed_best(
        _blocking_rank, engine, params, x, page, repeat=3)

    def _runner_rank():
        return runner.run(params, x, RankTop1Sink("margin"))

    (order_run, top1_run), us_run = timed_best(_runner_rank, repeat=3)

    # identical page packing -> bit-equal statistics -> identical rank
    assert np.array_equal(order_run, order_blk), \
        "sweep runner diverged from the blocking page loop"
    assert np.array_equal(top1_run, top1_blk)
    # agreement with the seed per-batch loop (different einsum contraction
    # -> fp tolerance: allow measure-zero argmax flips on near-tied logits)
    assert np.mean(top1_run == top1_host) > 0.999, \
        "sweep runner top1 diverged from the seed host loop"

    speedup_host = us_host / us_run
    speedup_blk = us_blk / us_run
    rows = [
        Row(f"sweep_hostloop_{pool}", us_host,
            f"{pool / (us_host / 1e6):.0f}rows/s"),
        Row(f"sweep_blocking_{pool}", us_blk,
            f"{pool / (us_blk / 1e6):.0f}rows/s"),
        Row(f"sweep_runner_{pool}", us_run,
            f"{pool / (us_run / 1e6):.0f}rows/s;"
            f"speedup={speedup_host:.1f}x_vs_hostloop,"
            f"{speedup_blk:.2f}x_vs_blocking"),
    ]

    # M(.) acquisition leg: device top-k reservoir vs host loop + argpartition
    k = 1024

    def _host_topk():
        stats, _ = score_pool_reference(model, params, x)
        scores = sel.uncertainty_scores("margin", stats)
        return np.argpartition(-scores, k - 1)[:k]

    host_top, us_htop = timed_best(_host_topk, repeat=2)
    dev_top, us_dtop = timed_best(
        lambda: runner.run(params, x, TopKSink(k, "margin")), repeat=3)
    overlap = len(set(dev_top.tolist()) & set(host_top.tolist()))
    assert overlap >= 0.999 * k, \
        "device top-k reservoir disagrees with the host selection"
    rows.append(Row(f"sweep_topk_{pool}_k{k}", us_dtop,
                    f"speedup={us_htop / us_dtop:.1f}x_vs_hostloop"))

    if enforce:
        assert speedup_host >= 2.0, \
            f"sweep runner only {speedup_host:.2f}x over the " \
            f"non-overlapped per-batch loop"
    return rows


def run_smoke() -> list:
    """CI smoke shape: same legs, same >= 2x gate, 20k-row pool."""
    return run_sweep(pool=20_000, page=4096, enforce=True)


def run() -> list:
    """Full bench: the 200k-row pool with the >= 2x gate enforced (the
    acceptance shape)."""
    return run_sweep(enforce=True)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", type=int, default=200_000)
    ap.add_argument("--page", type=int, default=16_384)
    ap.add_argument("--enforce", action="store_true",
                    help="assert the >= 2x speedup floor (the CI gate)")
    ap.add_argument("--smoke", action="store_true",
                    help="small-shape smoke mode (gate enforced)")
    args = ap.parse_args()
    rows = (run_smoke() if args.smoke else
            run_sweep(pool=args.pool, page=args.page, enforce=args.enforce))
    for r in rows:
        print(r.csv())
